//! Integration: the event-driven fast simulation path is bit-exact
//! against the per-tick reference path (ISSUE 10 acceptance).
//!
//! The skip-ahead scheduler is only allowed to exist because it is
//! indistinguishable from per-tick stepping: every test here runs the
//! same workload through both paths and demands byte-identical report
//! JSON — engine stall breakdowns, PC efficiency counters, FIFO peaks,
//! fault ledgers and all. Covered workloads:
//!
//! (a) every Table I zoo model, single device;
//! (b) a 2-shard fleet with credit-based inter-device links;
//! (c) a probed run (flight recorder attached): windowed samples are
//!     taken at identical cycles with identical cumulative counters;
//! (d) a seeded chaos run (HBM read errors + a thermal-throttle window)
//!     on one device, and a fleet run with a link stall, credit loss,
//!     and a replica outage;
//! (e) the `next_allowed` skip bound never jumps an allowed cycle
//!     inside a throttle window (checked against `denies()` directly).

use h2pipe::cluster::{partition, FleetConfig, FleetSim, PartitionOptions};
use h2pipe::compiler::compile;
use h2pipe::config::{CompilerOptions, DeviceConfig};
use h2pipe::faults::{
    next_allowed, FaultPlan, HbmFaultSpec, LinkFault, LinkFaultKind, ReplicaOutage, ThrottleWindow,
};
use h2pipe::nn::zoo;
use h2pipe::obs::Recorder;
use h2pipe::sim::pipeline::{PipelineSim, SimConfig};

fn device() -> DeviceConfig {
    DeviceConfig::stratix10_nx2100()
}

fn cfg(exact: bool) -> SimConfig {
    SimConfig { images: 3, warmup_images: 1, exact_stepping: exact, ..SimConfig::default() }
}

#[test]
fn fast_path_is_byte_identical_on_every_zoo_model() {
    let d = device();
    let o = CompilerOptions::default();
    for net in zoo::table1_models() {
        let plan = compile(&net, &d, &o).unwrap();
        let exact = PipelineSim::new(&net, &plan).unwrap().run(&cfg(true)).unwrap();
        let fast = PipelineSim::new(&net, &plan).unwrap().run(&cfg(false)).unwrap();
        assert_eq!(
            exact.to_json().to_string(),
            fast.to_json().to_string(),
            "{}: event path diverged from per-tick reference",
            net.name
        );
    }
}

#[test]
fn fast_path_is_byte_identical_on_a_two_shard_fleet() {
    let d = device();
    let net = zoo::resnet18();
    let o = CompilerOptions::default();
    let pp = partition(&net, &d, &o, &PartitionOptions { shards: Some(2), max_shards: 2 }).unwrap();
    let fleet = FleetSim::new(&pp).unwrap();
    let base = FleetConfig { images: 3, warmup_images: 1, ..FleetConfig::default() };
    let exact = fleet.run(&FleetConfig { exact_stepping: true, ..base.clone() }).unwrap();
    let fast = fleet.run(&FleetConfig { exact_stepping: false, ..base }).unwrap();
    assert_eq!(
        exact.to_json().to_string(),
        fast.to_json().to_string(),
        "fleet event path diverged from per-tick reference"
    );
}

#[test]
fn fast_path_is_byte_identical_with_a_recorder_attached() {
    let d = device();
    let net = zoo::resnet18();
    let plan = compile(&net, &d, &CompilerOptions::default()).unwrap();
    let run = |exact: bool| {
        let mut rec = Recorder::new(2048);
        let rep = PipelineSim::new(&net, &plan).unwrap().run_probed(&cfg(exact), &mut rec).unwrap();
        (rep.to_json().to_string(), rec.profile().to_string())
    };
    let (exact_rep, exact_prof) = run(true);
    let (fast_rep, fast_prof) = run(false);
    assert_eq!(exact_rep, fast_rep, "probed report diverged");
    assert_eq!(exact_prof, fast_prof, "recorder profile diverged");
}

#[test]
fn fast_path_is_byte_identical_under_seeded_chaos() {
    // HBM read errors force replay scheduling and a thermal throttle
    // denies CAS issue in a duty-cycled window — both perturb command
    // timing, so any scheduler skip over a window boundary would show
    // up as a diverged stall/fault ledger.
    let d = device();
    let net = zoo::resnet18();
    let plan = compile(&net, &d, &CompilerOptions::default()).unwrap();
    let mut fp = FaultPlan::new(7);
    fp.hbm = Some(HbmFaultSpec { start: 0, end: 200_000, prob: 0.01, max_replays: 3 });
    fp.throttle.push(ThrottleWindow { pc: 0, start: 1_000, end: 150_000, deny: 3, period: 8 });
    fp.throttle.push(ThrottleWindow { pc: 1, start: 50_000, end: 90_000, deny: 5, period: 16 });
    let run = |exact: bool| {
        let mut sim = PipelineSim::new(&net, &plan).unwrap();
        sim.apply_faults(&fp);
        sim.run(&cfg(exact)).unwrap().to_json().to_string()
    };
    assert_eq!(run(true), run(false), "chaos event path diverged from per-tick reference");
}

#[test]
fn fast_path_is_byte_identical_on_a_chaos_fleet() {
    let d = device();
    let net = zoo::resnet18();
    let o = CompilerOptions::default();
    let pp = partition(&net, &d, &o, &PartitionOptions { shards: Some(2), max_shards: 2 }).unwrap();
    let mut fp = FaultPlan::new(13);
    fp.hbm = Some(HbmFaultSpec { start: 0, end: 100_000, prob: 0.02, max_replays: 3 });
    fp.links.push(LinkFault { link: 0, start: 5_000, end: 60_000, kind: LinkFaultKind::Stall });
    fp.links.push(LinkFault {
        link: 0,
        start: 80_000,
        end: 400_000,
        kind: LinkFaultKind::CreditLoss(6),
    });
    fp.replicas.push(ReplicaOutage { replica: 0, start: 10_000, end: 90_000 });
    let run = |exact: bool| {
        let mut fleet = FleetSim::new(&pp).unwrap();
        fleet.apply_faults(&fp).unwrap();
        let cfg = FleetConfig {
            images: 3,
            warmup_images: 1,
            exact_stepping: exact,
            ..FleetConfig::default()
        };
        fleet.run(&cfg).unwrap().to_json().to_string()
    };
    assert_eq!(run(true), run(false), "chaos fleet event path diverged");
}

#[test]
fn next_allowed_never_jumps_an_allowed_cycle() {
    // The scheduler's throttle skip bound must land on the first cycle
    // the per-tick path would have issued at: every cycle it skips is
    // denied, and (when it converges) the landing cycle is allowed.
    let mut sets: Vec<Vec<ThrottleWindow>> = Vec::new();
    for period in [2u64, 5, 8] {
        for deny in 1..period {
            sets.push(vec![ThrottleWindow { pc: 0, start: 10, end: 100, deny, period }]);
        }
    }
    // overlapping pair with different phases/periods
    sets.push(vec![
        ThrottleWindow { pc: 0, start: 0, end: 120, deny: 2, period: 6 },
        ThrottleWindow { pc: 0, start: 30, end: 80, deny: 3, period: 4 },
    ]);
    for ws in &sets {
        for from in 0..160u64 {
            let a = next_allowed(ws, from);
            assert!(a >= from);
            for c in from..a {
                assert!(
                    ws.iter().any(|w| w.denies(c)),
                    "skip from {from} to {a} jumped allowed cycle {c} ({ws:?})"
                );
            }
            assert!(
                !ws.iter().any(|w| w.denies(a)),
                "landing cycle {a} from {from} is still denied ({ws:?})"
            );
        }
    }
}
