//! Integration: runtime backend + serving coordinator.
//!
//! Runs unconditionally in the offline crate set: `Runtime::cpu` resolves
//! to the pure-Rust reference interpreter by default, and to the PJRT
//! client against the real AOT artifacts under `--features pjrt` (after
//! `make artifacts`). The assertions hold for both backends — they pin
//! the int8-datapath contract, not backend-specific numerics.

use h2pipe::coordinator::{InferenceServer, ServerConfig};
use h2pipe::runtime::Runtime;

fn artifact_dir() -> String {
    format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
}

#[test]
fn both_models_load_and_execute() {
    let rt = Runtime::cpu(artifact_dir()).unwrap();

    let cifar = rt.load("cifarnet").unwrap();
    let out = cifar.run_i32(&vec![3i32; 32 * 32 * 3], &[32, 32, 3]).unwrap();
    assert_eq!(out.len(), 10);
    assert!(out.iter().all(|&v| (-128..=127).contains(&v)), "int8-ranged logits");

    let block = rt.load("resnet_block").unwrap();
    let x = vec![1i32; 56 * 56 * 64];
    let y = block.run_i32(&x, &[56, 56, 64]).unwrap();
    assert_eq!(y.len(), 56 * 56 * 64);
    // block output is post-ReLU
    assert!(y.iter().all(|&v| (0..=127).contains(&v)));
}

#[test]
fn model_outputs_differ_across_inputs() {
    let rt = Runtime::cpu(artifact_dir()).unwrap();
    let exe = rt.load("cifarnet").unwrap();
    let a = exe.run_i32(&vec![1i32; 32 * 32 * 3], &[32, 32, 3]).unwrap();
    let b = exe.run_i32(&vec![-7i32; 32 * 32 * 3], &[32, 32, 3]).unwrap();
    assert_ne!(a, b, "different inputs must produce different logits");
}

#[test]
fn int8_clipping_at_model_boundary() {
    let rt = Runtime::cpu(artifact_dir()).unwrap();
    let exe = rt.load("cifarnet").unwrap();
    // out-of-int8-range inputs are clipped inside the graph: 500 -> 127
    let wide = exe.run_i32(&vec![500i32; 32 * 32 * 3], &[32, 32, 3]).unwrap();
    let clipped = exe.run_i32(&vec![127i32; 32 * 32 * 3], &[32, 32, 3]).unwrap();
    assert_eq!(wide, clipped);
}

#[test]
fn server_backpressure_rejects_when_overloaded() {
    let mut cfg = ServerConfig::cifarnet(&artifact_dir());
    cfg.queue_depth = 1;
    cfg.batch_size = 1;
    let srv = std::sync::Arc::new(InferenceServer::start(cfg).unwrap());
    // flood from several threads; some requests may be rejected, none may
    // hang, and completed + rejected must equal submitted
    let mut handles = Vec::new();
    for t in 0..4u64 {
        let s = srv.clone();
        handles.push(std::thread::spawn(move || {
            let img = vec![t as i32; 32 * 32 * 3];
            let mut ok = 0u64;
            let mut rejected = 0u64;
            for _ in 0..10 {
                match s.infer(img.clone()) {
                    Ok(_) => ok += 1,
                    Err(_) => rejected += 1,
                }
            }
            (ok, rejected)
        }));
    }
    let mut total_ok = 0;
    let mut total_rej = 0;
    for h in handles {
        let (o, r) = h.join().unwrap();
        total_ok += o;
        total_rej += r;
    }
    assert_eq!(total_ok + total_rej, 40);
    let rep = std::sync::Arc::into_inner(srv).unwrap().shutdown();
    assert_eq!(rep.completed, total_ok);
}

#[test]
fn server_batches_under_load() {
    let mut cfg = ServerConfig::cifarnet(&artifact_dir());
    cfg.batch_size = 8;
    cfg.batch_timeout = std::time::Duration::from_millis(20);
    let srv = std::sync::Arc::new(InferenceServer::start(cfg).unwrap());
    let mut handles = Vec::new();
    for t in 0..8u64 {
        let s = srv.clone();
        handles.push(std::thread::spawn(move || {
            let img = vec![t as i32; 32 * 32 * 3];
            for _ in 0..6 {
                let _ = s.infer(img.clone());
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let rep = std::sync::Arc::into_inner(srv).unwrap().shutdown();
    assert!(rep.completed > 0);
    assert!(
        rep.mean_batch > 1.05,
        "8 concurrent clients should produce some batching: {:.2}",
        rep.mean_batch
    );
}

#[test]
fn reference_backend_always_available() {
    // Even with the pjrt feature on, the reference interpreter must work
    // with no artifacts — it is the serving fallback.
    let rt = Runtime::reference(artifact_dir());
    assert_eq!(rt.backend_name(), "reference");
    let exe = rt.load("cifarnet").unwrap();
    let out = exe.run_int8(&[5i8; 32 * 32 * 3], &[32, 32, 3]).unwrap();
    assert_eq!(out.len(), 10);
}
