//! Integration: compiler -> cycle simulator across the model zoo, and the
//! paper's qualitative claims end to end — routed through the
//! `h2pipe::session` pipeline API (builder -> CompiledModel -> simulate).

use h2pipe::config::{BurstLengthPolicy, CompilerOptions, DeviceConfig, WeightPlacement};
use h2pipe::nn::{zoo, Network};
use h2pipe::session::{CompiledModel, Session};
use h2pipe::sim::pipeline::{SimConfig, SimReport};

fn device() -> DeviceConfig {
    DeviceConfig::stratix10_nx2100()
}

fn quick() -> SimConfig {
    SimConfig { images: 3, warmup_images: 1, ..SimConfig::default() }
}

/// Compile one network through the session pipeline.
fn compiled(net: Network, o: CompilerOptions) -> CompiledModel {
    let name = net.name.clone();
    Session::builder()
        .network(net)
        .device(device())
        .options(o)
        .compile()
        .unwrap_or_else(|e| panic!("{name}: {e:#}"))
}

fn simulated(cm: &CompiledModel) -> SimReport {
    cm.simulate(&quick()).unwrap_or_else(|e| panic!("{}: {e:#}", cm.network().name))
}

#[test]
fn every_zoo_model_compiles_and_simulates() {
    for net in zoo::table1_models() {
        let cm = compiled(net, CompilerOptions::default());
        let rep = simulated(&cm);
        let name = &cm.network().name;
        assert!(rep.throughput > 50.0, "{name}: {:.0} im/s", rep.throughput);
        assert!(rep.latency > 0.0 && rep.latency < 1.0, "{name}: {}s", rep.latency);
    }
}

#[test]
fn paper_headline_shape_hybrid_vs_all_hbm() {
    // Fig. 6 shape: hybrid > all-HBM for all three evaluation networks,
    // with ResNet-18 gaining the most (its weights mostly fit on chip).
    let mut gains = Vec::new();
    for net in zoo::eval_models() {
        let name = net.name.clone();
        let hybrid = compiled(net.clone(), CompilerOptions::default());
        let mut o = CompilerOptions::default();
        o.all_hbm = true;
        let all = compiled(net, o);
        let rh = simulated(&hybrid);
        let ra = simulated(&all);
        assert!(
            rh.throughput > ra.throughput,
            "{name}: hybrid {:.0} <= all-HBM {:.0}",
            rh.throughput,
            ra.throughput
        );
        gains.push((name, rh.throughput / ra.throughput));
    }
    let r18 = gains.iter().find(|(n, _)| n == "ResNet-18").unwrap().1;
    let vgg = gains.iter().find(|(n, _)| n == "VGG-16").unwrap().1;
    assert!(r18 > vgg, "R18 hybrid gain {r18:.2} should exceed VGG {vgg:.2}");
}

#[test]
fn paper_throughput_ordering_r18_r50_vgg() {
    let mut t = Vec::new();
    for net in zoo::eval_models() {
        t.push(simulated(&compiled(net, CompilerOptions::default())).throughput);
    }
    assert!(t[0] > t[1], "R18 {:.0} > R50 {:.0}", t[0], t[1]);
    assert!(t[1] > t[2], "R50 {:.0} > VGG {:.0}", t[1], t[2]);
}

#[test]
fn table2_shape_burst_length_sensitivity() {
    // R18's bottleneck is on-chip: BL8 == BL16 throughput. R50's is on
    // HBM: throughput must not decrease as BL grows.
    let run = |name: &str, bl: u32| {
        let mut o = CompilerOptions::default();
        o.burst_length = BurstLengthPolicy::Fixed(bl);
        simulated(&compiled(zoo::by_name(name).unwrap(), o)).throughput
    };
    let r18_8 = run("resnet18", 8);
    let r18_16 = run("resnet18", 16);
    assert!(
        (r18_8 - r18_16).abs() / r18_8 < 0.02,
        "R18 flat across BL: {r18_8:.0} vs {r18_16:.0}"
    );
    let r50_8 = run("resnet50", 8);
    let r50_32 = run("resnet50", 32);
    assert!(
        r50_32 >= r50_8 * 0.995,
        "R50 should gain (or hold) with BL: {r50_8:.0} -> {r50_32:.0}"
    );
}

#[test]
fn mobilenets_identical_to_hpipe_baseline() {
    // Networks that fit on chip never touch HBM: H2PIPE == HPIPE.
    for name in ["mobilenetv1", "mobilenetv2", "mobilenetv3"] {
        let cm = compiled(zoo::by_name(name).unwrap(), CompilerOptions::default());
        assert_eq!(cm.plan().hbm_layers().count(), 0, "{name}");
        let rep = simulated(&cm);
        assert_eq!(rep.freeze_fraction, 0.0, "{name}");
    }
}

#[test]
fn all_hbm_vgg_offloads_every_weight_layer_it_can() {
    let mut o = CompilerOptions::default();
    o.all_hbm = true;
    let cm = compiled(zoo::vgg16(), o);
    let plan = cm.plan();
    // every weight layer either offloaded or blocked by chain bandwidth
    let onchip: Vec<_> = plan.onchip_layers().map(|l| l.stats.name.clone()).collect();
    for l in plan.onchip_layers() {
        assert!(
            l.par.chains() as u64 > plan.free_bw_slots,
            "{} kept on chip despite {} free slots",
            l.stats.name,
            plan.free_bw_slots
        );
    }
    // VGG-16 has few layers: nearly all should be on HBM
    assert!(onchip.len() <= 2, "on-chip remnants: {onchip:?}");
}

#[test]
fn latency_scales_with_pipeline_depth() {
    let r18 = simulated(&compiled(zoo::resnet18(), CompilerOptions::default())).latency;
    let r50 = simulated(&compiled(zoo::resnet50(), CompilerOptions::default())).latency;
    assert!(r50 > r18, "deeper net, longer latency: {r50} vs {r18}");
}

#[test]
fn simulation_is_deterministic() {
    let cm = compiled(zoo::resnet50(), CompilerOptions::default());
    let a = simulated(&cm);
    let b = simulated(&cm);
    assert_eq!(a.throughput, b.throughput);
    assert_eq!(a.latency, b.latency);
    assert_eq!(a.core_cycles, b.core_cycles);
}

#[test]
fn plan_resource_usage_is_consistent() {
    for net in zoo::eval_models() {
        let name = net.name.clone();
        let cm = compiled(net, CompilerOptions::default());
        let plan = cm.plan();
        let u = plan.recompute_usage();
        assert_eq!(u.m20k, plan.usage.m20k, "{name}");
        assert_eq!(u.tensor_blocks, plan.usage.tensor_blocks);
        assert_eq!(u.alms, plan.usage.alms);
        // offloaded layers must carry PC assignments and vice versa
        for l in &plan.layers {
            match l.placement {
                WeightPlacement::Hbm => assert!(!l.pcs.is_empty(), "{}", l.stats.name),
                WeightPlacement::OnChip => assert!(l.pcs.is_empty(), "{}", l.stats.name),
            }
        }
    }
}
