//! Integration: compiler -> cycle simulator across the model zoo, and the
//! paper's qualitative claims end to end.

use h2pipe::compiler::compile;
use h2pipe::config::{BurstLengthPolicy, CompilerOptions, DeviceConfig, WeightPlacement};
use h2pipe::nn::zoo;
use h2pipe::sim::pipeline::{simulate, SimConfig};

fn device() -> DeviceConfig {
    DeviceConfig::stratix10_nx2100()
}

fn quick() -> SimConfig {
    SimConfig { images: 3, warmup_images: 1, ..SimConfig::default() }
}

#[test]
fn every_zoo_model_compiles_and_simulates() {
    let d = device();
    let o = CompilerOptions::default();
    for net in zoo::table1_models() {
        let plan = compile(&net, &d, &o).unwrap_or_else(|e| panic!("{}: {e}", net.name));
        let rep = simulate(&net, &plan, &quick()).unwrap_or_else(|e| panic!("{}: {e}", net.name));
        assert!(rep.throughput > 50.0, "{}: {:.0} im/s", net.name, rep.throughput);
        assert!(rep.latency > 0.0 && rep.latency < 1.0, "{}: {}s", net.name, rep.latency);
    }
}

#[test]
fn paper_headline_shape_hybrid_vs_all_hbm() {
    // Fig. 6 shape: hybrid > all-HBM for all three evaluation networks,
    // with ResNet-18 gaining the most (its weights mostly fit on chip).
    let d = device();
    let mut gains = Vec::new();
    for net in zoo::eval_models() {
        let hybrid = compile(&net, &d, &CompilerOptions::default()).unwrap();
        let mut o = CompilerOptions::default();
        o.all_hbm = true;
        let all = compile(&net, &d, &o).unwrap();
        let rh = simulate(&net, &hybrid, &quick()).unwrap();
        let ra = simulate(&net, &all, &quick()).unwrap();
        assert!(
            rh.throughput > ra.throughput,
            "{}: hybrid {:.0} <= all-HBM {:.0}",
            net.name,
            rh.throughput,
            ra.throughput
        );
        gains.push((net.name.clone(), rh.throughput / ra.throughput));
    }
    let r18 = gains.iter().find(|(n, _)| n == "ResNet-18").unwrap().1;
    let vgg = gains.iter().find(|(n, _)| n == "VGG-16").unwrap().1;
    assert!(r18 > vgg, "R18 hybrid gain {r18:.2} should exceed VGG {vgg:.2}");
}

#[test]
fn paper_throughput_ordering_r18_r50_vgg() {
    let d = device();
    let o = CompilerOptions::default();
    let mut t = Vec::new();
    for net in zoo::eval_models() {
        let plan = compile(&net, &d, &o).unwrap();
        t.push(simulate(&net, &plan, &quick()).unwrap().throughput);
    }
    assert!(t[0] > t[1], "R18 {:.0} > R50 {:.0}", t[0], t[1]);
    assert!(t[1] > t[2], "R50 {:.0} > VGG {:.0}", t[1], t[2]);
}

#[test]
fn table2_shape_burst_length_sensitivity() {
    // R18's bottleneck is on-chip: BL8 == BL16 throughput. R50's is on
    // HBM: throughput must not decrease as BL grows.
    let d = device();
    let run = |name: &str, bl: u32| {
        let net = zoo::by_name(name).unwrap();
        let mut o = CompilerOptions::default();
        o.burst_length = BurstLengthPolicy::Fixed(bl);
        let plan = compile(&net, &d, &o).unwrap();
        simulate(&net, &plan, &quick()).unwrap().throughput
    };
    let r18_8 = run("resnet18", 8);
    let r18_16 = run("resnet18", 16);
    assert!(
        (r18_8 - r18_16).abs() / r18_8 < 0.02,
        "R18 flat across BL: {r18_8:.0} vs {r18_16:.0}"
    );
    let r50_8 = run("resnet50", 8);
    let r50_32 = run("resnet50", 32);
    assert!(
        r50_32 >= r50_8 * 0.995,
        "R50 should gain (or hold) with BL: {r50_8:.0} -> {r50_32:.0}"
    );
}

#[test]
fn mobilenets_identical_to_hpipe_baseline() {
    // Networks that fit on chip never touch HBM: H2PIPE == HPIPE.
    let d = device();
    let o = CompilerOptions::default();
    for name in ["mobilenetv1", "mobilenetv2", "mobilenetv3"] {
        let net = zoo::by_name(name).unwrap();
        let plan = compile(&net, &d, &o).unwrap();
        assert_eq!(plan.hbm_layers().count(), 0, "{name}");
        let rep = simulate(&net, &plan, &quick()).unwrap();
        assert_eq!(rep.freeze_fraction, 0.0, "{name}");
    }
}

#[test]
fn all_hbm_vgg_offloads_every_weight_layer_it_can() {
    let d = device();
    let mut o = CompilerOptions::default();
    o.all_hbm = true;
    let net = zoo::vgg16();
    let plan = compile(&net, &d, &o).unwrap();
    // every weight layer either offloaded or blocked by chain bandwidth
    let onchip: Vec<_> = plan.onchip_layers().map(|l| l.stats.name.clone()).collect();
    for l in plan.onchip_layers() {
        assert!(
            l.par.chains() as u64 > plan.free_bw_slots,
            "{} kept on chip despite {} free slots",
            l.stats.name,
            plan.free_bw_slots
        );
    }
    // VGG-16 has few layers: nearly all should be on HBM
    assert!(onchip.len() <= 2, "on-chip remnants: {onchip:?}");
}

#[test]
fn latency_scales_with_pipeline_depth() {
    let d = device();
    let o = CompilerOptions::default();
    let r18 = {
        let net = zoo::resnet18();
        let plan = compile(&net, &d, &o).unwrap();
        simulate(&net, &plan, &quick()).unwrap().latency
    };
    let r50 = {
        let net = zoo::resnet50();
        let plan = compile(&net, &d, &o).unwrap();
        simulate(&net, &plan, &quick()).unwrap().latency
    };
    assert!(r50 > r18, "deeper net, longer latency: {r50} vs {r18}");
}

#[test]
fn simulation_is_deterministic() {
    let d = device();
    let o = CompilerOptions::default();
    let net = zoo::resnet50();
    let plan = compile(&net, &d, &o).unwrap();
    let a = simulate(&net, &plan, &quick()).unwrap();
    let b = simulate(&net, &plan, &quick()).unwrap();
    assert_eq!(a.throughput, b.throughput);
    assert_eq!(a.latency, b.latency);
    assert_eq!(a.core_cycles, b.core_cycles);
}

#[test]
fn plan_resource_usage_is_consistent() {
    let d = device();
    let o = CompilerOptions::default();
    for net in zoo::eval_models() {
        let plan = compile(&net, &d, &o).unwrap();
        let u = plan.recompute_usage();
        assert_eq!(u.m20k, plan.usage.m20k, "{}", net.name);
        assert_eq!(u.tensor_blocks, plan.usage.tensor_blocks);
        assert_eq!(u.alms, plan.usage.alms);
        // offloaded layers must carry PC assignments and vice versa
        for l in &plan.layers {
            match l.placement {
                WeightPlacement::Hbm => assert!(!l.pcs.is_empty(), "{}", l.stats.name),
                WeightPlacement::OnChip => assert!(l.pcs.is_empty(), "{}", l.stats.name),
            }
        }
    }
}
