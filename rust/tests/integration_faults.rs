//! Integration: deterministic fault injection + recovery (ISSUE 8
//! acceptance).
//!
//! (a) a serving soak with a mid-run replica kill loses nothing: every
//!     request leaves through a counted door and the recovery machinery
//!     (failover + watchdog reboot) keeps the tail bounded;
//! (b) HBM fault replays never break the controller's outstanding-beat
//!     bound, and the per-PC ledger conserves (injected == replays +
//!     drops);
//! (c) same-seed chaos simulations are byte-identical, different seeds
//!     are not, and healthy runs keep their pre-fault report shape;
//! (d) the `h2pipe.faults/v1` artifact round-trips through disk and
//!     rejects foreign format tags;
//! (e) a sharded fleet run with an HBM error burst, a link stall, and a
//!     replica crash-then-rejoin conserves lines and replays
//!     byte-identically.

use h2pipe::cluster::{FleetConfig, PartitionOptions};
use h2pipe::config::DeviceConfig;
use h2pipe::faults::{
    FaultPlan, HbmFaultSpec, LinkFault, LinkFaultKind, ReplicaOutage, ServeFault, ServeFaultKind,
};
use h2pipe::hbm::controller::{Dir, PcTuning, PseudoChannel, Request};
use h2pipe::hbm::CmdBus;
use h2pipe::session::{CompiledModel, DeploymentTarget, ServeOptions, Session};
use h2pipe::sim::pipeline::SimConfig;
use h2pipe::testkit::{check, Gen};
use h2pipe::util::Json;

fn compiled_resnet18() -> CompiledModel {
    Session::builder()
        .model("resnet18")
        .device(DeviceConfig::stratix10_nx2100())
        .compile()
        .unwrap()
}

fn artifact_dir() -> String {
    format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
}

#[test]
fn chaos_serve_soak_survives_a_mid_run_replica_kill() {
    // (a): 3 replicas, replica 1 crashes after 4 served requests, the
    // watchdog reboots it while 2 clients keep the soak running.
    let cm = compiled_resnet18();
    let mut plan = FaultPlan::new(11);
    plan.serve =
        vec![ServeFault { replica: 1, kind: ServeFaultKind::Crash { after_requests: 4 } }];
    plan.recovery.watchdog_ms = 2;
    plan.recovery.backoff_ms = 1;
    let deadline_ms = plan.recovery.request_deadline_ms as f64;
    let opts = ServeOptions {
        requests: 240,
        batch: 4,
        replicas: 3,
        clients: 2,
        artifact_dir: artifact_dir(),
        // ~1 ms modelled service time stretches the soak far past the
        // watchdog period, so the reboot happens mid-run, not post-run.
        modelled_image_s: Some(0.001),
        ..ServeOptions::default()
    };
    let rep = cm.deploy(DeploymentTarget::Serve(opts)).with_faults(plan).run().unwrap();

    let f = rep.detail.get("faults").expect("armed run must carry the fault ledger");
    let s = f.to_string();
    assert!(s.contains("\"lost\":0"), "a request vanished: {s}");
    assert!(
        f.get("recovered").and_then(Json::as_u64).unwrap() > 0,
        "the crash must surface as failover and/or reboot: {s}"
    );
    assert!(
        f.get("reboots").and_then(Json::as_u64).unwrap() >= 1,
        "watchdog must reboot the crashed replica mid-soak: {s}"
    );
    // conservation at the client boundary: every submitted request
    // completed or was rejected — none hang, none are lost
    let m = rep.detail.get("metrics").unwrap();
    let completed = m.get("completed").and_then(Json::as_u64).unwrap();
    let rejected = m.get("rejected").and_then(Json::as_u64).unwrap();
    assert_eq!(completed + rejected, 240, "request accounting broken");
    assert!(completed > 0, "the soak must make progress through the crash");
    // bounded tail: a successful request's last attempt starts inside the
    // router deadline and is itself server-deadline-bounded
    let p99 = m.get("p99_ms").and_then(Json::as_f64).unwrap();
    assert!(p99.is_finite() && p99 < 2.0 * deadline_ms, "p99 {p99} ms unbounded");
}

#[test]
fn prop_fault_replays_respect_the_outstanding_beat_bound() {
    // (b): random read traffic against an armed PC — the queued-beat
    // bound must hold on every cycle (replays restore exactly what the
    // faulted issue subtracted), and the per-PC ledger must conserve.
    let d = DeviceConfig::stratix10_nx2100();
    check("hbm-fault-queue-bound", 15, |g: &mut Gen| {
        let mut pc = PseudoChannel::new(
            &d.hbm,
            &d.hbm_timing,
            PcTuning { outstanding_beats: g.u32(32, 128), lookahead: g.usize(1, 8) },
        );
        pc.inject_faults(
            Some(HbmFaultSpec {
                start: 0,
                end: 100_000,
                prob: 0.2,
                max_replays: g.u32(0, 3),
            }),
            Vec::new(),
            g.u64(1, u64::MAX - 1),
        );
        let bursts = [1u32, 2, 4, 8, 16, 32];
        let mut id = 0u64;
        let mut step = |pc: &mut PseudoChannel| -> Option<String> {
            let mut bus = CmdBus::new();
            pc.tick(&mut bus);
            pc.drain_completions();
            if pc.queued_beats() > pc.outstanding_limit() {
                return Some(format!(
                    "queued {} beats > bound {}",
                    pc.queued_beats(),
                    pc.outstanding_limit()
                ));
            }
            None
        };
        for _ in 0..g.usize(3_000, 8_000) {
            let bl = *g.choose(&bursts);
            if g.bool(0.7) && pc.can_accept(bl) {
                let addr = g.u64(0, (1 << 26) - 1) & !31;
                pc.push(Request { id, dir: Dir::Read, addr, burst: bl });
                id += 1;
            }
            if let Some(e) = step(&mut pc) {
                return Err(e);
            }
        }
        let mut guard = 0u64;
        while !pc.is_idle() {
            if let Some(e) = step(&mut pc) {
                return Err(e);
            }
            guard += 1;
            if guard > 2_000_000 {
                return Err("drain did not converge under fault replay".into());
            }
        }
        let st = &pc.stats;
        if st.faults_injected == 0 {
            return Err("a 20% in-window fault rate must fire".into());
        }
        if st.faults_injected != st.fault_replays + st.faults_dropped {
            return Err(format!(
                "PC ledger broken: {} injected != {} replays + {} drops",
                st.faults_injected, st.fault_replays, st.faults_dropped
            ));
        }
        Ok(())
    });
}

#[test]
fn chaos_simulate_reports_are_byte_identical_per_seed() {
    // (c): determinism is the contract the CI chaos step diffs on.
    let cm = compiled_resnet18();
    let cfg = SimConfig { images: 3, warmup_images: 1, ..SimConfig::default() };
    let run = |seed: u64| {
        cm.deploy(DeploymentTarget::SingleDevice(cfg.clone()))
            .with_faults(FaultPlan::chaos_preset(seed))
            .run()
            .unwrap()
            .to_json()
            .to_string()
    };
    let a = run(42);
    assert_eq!(a, run(42), "same seed, same workload => byte-identical report");
    assert_ne!(a, run(43), "a different seed must perturb the injected faults");

    let f = Json::parse(&a)
        .unwrap()
        .get("detail")
        .and_then(|d| d.get("faults"))
        .cloned()
        .expect("armed simulate must report the ledger");
    assert!(f.get("injected").and_then(Json::as_u64).unwrap() > 0, "{f}");
    assert_eq!(f.get("lost").and_then(Json::as_u64), Some(0), "{f}");
    assert!(f.get("recovered").and_then(Json::as_u64).unwrap() > 0, "{f}");

    // healthy runs keep their pre-fault shape: no faults key at all
    let healthy = cm
        .deploy(DeploymentTarget::SingleDevice(cfg.clone()))
        .run()
        .unwrap()
        .to_json()
        .to_string();
    assert!(!healthy.contains("\"faults\""), "healthy report grew a faults block: {healthy}");
}

#[test]
fn fault_plan_artifact_round_trips_and_rejects_bad_format() {
    // (d): the h2pipe.faults/v1 artifact follows the plan-artifact
    // discipline — stable bytes, strict format tag.
    let dir = std::env::temp_dir().join("h2pipe_faults_roundtrip");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("chaos.json");
    let plan = FaultPlan::chaos_preset(9);
    plan.save(&path).unwrap();
    let loaded = FaultPlan::load(&path).unwrap();
    assert_eq!(plan, loaded, "round-trip must preserve every section");
    assert_eq!(plan.to_json().to_string(), loaded.to_json().to_string());

    let bad = dir.join("bad.json");
    let text = std::fs::read_to_string(&path).unwrap().replace("faults/v1", "faults/v9");
    std::fs::write(&bad, text).unwrap();
    let err = FaultPlan::load(&bad).unwrap_err();
    assert!(format!("{err:#}").contains("format"), "{err:#}");
}

#[test]
fn fleet_chaos_crash_then_rejoin_conserves_and_replays_identically() {
    // (e): HBM burst + link stall + replica outage on a 2-shard,
    // 2-replica fleet. The outage freezes replica 1 mid-run; it rejoins
    // and the run must still conserve lines and reproduce byte-for-byte.
    let cm = compiled_resnet18();
    let mut plan = FaultPlan::new(5);
    plan.hbm = Some(HbmFaultSpec { start: 0, end: 150_000, prob: 0.05, max_replays: 2 });
    plan.links = vec![LinkFault { link: 0, start: 5_000, end: 40_000, kind: LinkFaultKind::Stall }];
    plan.replicas = vec![ReplicaOutage { replica: 1, start: 10_000, end: 60_000 }];
    let target = DeploymentTarget::Fleet {
        partition: PartitionOptions { shards: Some(2), max_shards: 2 },
        fleet: FleetConfig { images: 3, warmup_images: 1, replicas: 2, ..FleetConfig::default() },
    };
    let run = || {
        cm.deploy(target.clone()).with_faults(plan.clone()).run().unwrap().to_json().to_string()
    };
    let a = run();
    assert_eq!(a, run(), "crash-then-rejoin must be deterministic");

    let f = Json::parse(&a)
        .unwrap()
        .get("detail")
        .and_then(|d| d.get("faults"))
        .cloned()
        .expect("armed fleet run must report the ledger");
    assert!(f.get("injected").and_then(Json::as_u64).unwrap() > 0, "{f}");
    assert_eq!(f.get("lost").and_then(Json::as_u64), Some(0), "{f}");
    assert!(f.get("link_stall_ticks").and_then(Json::as_u64).unwrap() > 0, "{f}");
    assert!(f.get("outage_ticks").and_then(Json::as_u64).unwrap() > 0, "{f}");
}
