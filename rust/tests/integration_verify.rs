//! Integration: the `h2pipe check` static plan verifier.
//!
//! Three claims, end to end:
//!
//! 1. **Clean by construction** — every default-compiled zoo plan the
//!    issue names produces zero diagnostics of any severity.
//! 2. **Each defect is caught, precisely** — the golden bad-plan fixtures
//!    under `tests/fixtures/bad_plans/` each trip exactly the one
//!    diagnostic code they were seeded with, and nothing else.
//! 3. **The static deadlock rule agrees with the simulator** — the
//!    H2P030 predicate matches the executable Fig. 5 reproduction
//!    (`fabric::deadlock`) in both flow-control modes.

use std::path::PathBuf;

use h2pipe::cluster::{partition, partition_at, PartitionOptions};
use h2pipe::config::{BurstLengthPolicy, CompilerOptions, FlowControl};
use h2pipe::fabric::deadlock::ScenarioConfig;
use h2pipe::fabric::{run_shared_pc_pipeline, PipelineOutcome};
use h2pipe::nn::{zoo, ConvKind, Network, OpKind, Shape};
use h2pipe::session::{codec, CompiledModel, DeploymentTarget, Session};
use h2pipe::sim::pipeline::SimConfig;
use h2pipe::testkit;
use h2pipe::util::Json;
use h2pipe::verify::deadlock::scenario_has_hazard;
use h2pipe::verify::{
    analyze_plan, check_artifact, check_partition, Code, DeadlockVerdict, Report, Severity,
};

const CLEAN_MODELS: [&str; 3] = ["resnet50", "vgg16", "mobilenet_edge"];

fn compile(model: &str) -> CompiledModel {
    Session::builder().model(model).compile().unwrap()
}

fn fixture_path(slug: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures/bad_plans")
        .join(format!("{slug}.json"))
}

/// Persist `cm` as the golden fixture `slug`, reload it from disk through
/// the unchecked path, and assert the verifier reports exactly the seeded
/// code and nothing else.
fn assert_fixture(slug: &str, cm: &CompiledModel, expect: Code) {
    let path = fixture_path(slug);
    testkit::golden(&path, &cm.to_json().to_pretty()).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let loaded = CompiledModel::from_json_unchecked(&Json::parse(&text).unwrap()).unwrap();
    let report = check_artifact(&loaded);
    assert_codes(&report, &[expect], slug);
}

fn assert_codes(report: &Report, expect: &[Code], ctx: &str) {
    let got: Vec<&str> = report.diagnostics.iter().map(|d| d.code.as_str()).collect();
    let want: Vec<&str> = expect.iter().map(|c| c.as_str()).collect();
    assert_eq!(got, want, "{ctx}: {}", report.render());
}

/// Re-derive every stored scalar after a structural mutation, exactly the
/// way `compile()` produces them — so the *only* inconsistency left is
/// the one the fixture seeds.
fn recanonicalize(cm: CompiledModel) -> CompiledModel {
    let (net, mut plan, mut prov) = cm.into_parts();
    plan.usage = plan.recompute_usage();
    plan.bottleneck_cycles = plan.recompute_bottleneck_cycles();
    plan.free_bw_slots = plan.recompute_free_bw_slots();
    plan.hbm_read_efficiency = plan.options.efficiency.lookup(plan.burst_len);
    let (tp, lat) = plan.analytic_estimates();
    plan.est_throughput = tp;
    plan.est_latency = lat;
    prov.options_hash = codec::options_hash(&plan.options);
    CompiledModel::from_parts(net, plan, prov)
}

/// Mutate a freshly compiled model's parts.
fn mutated(
    model: &str,
    f: impl FnOnce(&mut h2pipe::compiler::AcceleratorPlan, &mut h2pipe::session::Provenance),
) -> CompiledModel {
    let (net, mut plan, mut prov) = compile(model).into_parts();
    f(&mut plan, &mut prov);
    CompiledModel::from_parts(net, plan, prov)
}

// ---------------------------------------------------------- clean plans

#[test]
fn default_compiled_zoo_plans_are_clean() {
    for model in CLEAN_MODELS {
        let cm = compile(model);
        let report = check_artifact(&cm);
        assert!(
            report.is_clean(),
            "{model} must verify clean (zero diagnostics of any severity):\n{}",
            report.render()
        );
    }
}

#[test]
fn run_report_carries_empty_diagnostics_for_clean_plans() {
    let cm = compile("resnet50");
    let cfg = SimConfig { images: 2, warmup_images: 1, ..SimConfig::default() };
    let rep = cm.deploy(DeploymentTarget::SingleDevice(cfg)).run().unwrap();
    assert!(rep.diagnostics.is_empty(), "post-compile check must be clean");
    assert!(rep.to_json().to_string().contains("\"diagnostics\":[]"));
    assert!(!rep.summary().contains("check:"), "clean summary stays unchanged");
}

// ---------------------------------------- family 1: resource overcommit

#[test]
fn fixture_h2p001_m20k_overcommit() {
    let cm = mutated("resnet50", |plan, _| {
        plan.device.m20k_blocks = plan.usage.m20k as u32 - 1;
    });
    assert_fixture("h2p001_m20k_overcommit", &cm, Code::M20kOvercommit);
    // feasibility findings do NOT block loading: `load` must accept this
    let loaded = CompiledModel::from_json(&cm.to_json()).unwrap();
    assert_eq!(loaded.network().name, cm.network().name);
}

#[test]
fn fixture_h2p002_tensor_block_overcommit() {
    let cm = mutated("resnet50", |plan, _| {
        plan.device.tensor_blocks = plan.usage.tensor_blocks as u32 - 1;
    });
    assert_fixture("h2p002_tensor_block_overcommit", &cm, Code::TensorBlockOvercommit);
}

#[test]
fn fixture_h2p003_alm_overcommit() {
    let cm = mutated("resnet50", |plan, _| {
        plan.device.alms = plan.usage.alms as u32 - 1;
    });
    assert_fixture("h2p003_alm_overcommit", &cm, Code::AlmOvercommit);
}

#[test]
fn fixture_h2p004_usage_tamper() {
    // decrease (not increase) so no overcommit rides along
    let cm = mutated("resnet50", |plan, _| {
        plan.usage.m20k -= 100;
    });
    assert_fixture("h2p004_usage_tamper", &cm, Code::UsageMismatch);
    // integrity findings DO block loading
    let err = CompiledModel::from_json(&cm.to_json()).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("integrity"), "{msg}");
    assert!(msg.contains("H2P004"), "{msg}");
}

// ------------------------------------ family 2: PC structure + bandwidth

#[test]
fn fixture_h2p010_illegal_pc() {
    let cm = mutated("resnet50", |plan, _| {
        let l = plan
            .layers
            .iter_mut()
            .find(|l| !l.pcs.is_empty())
            .expect("resnet50 offloads layers");
        // PC16 is the §V-B excluded channel; slot count stays the same so
        // only the legality rule fires
        l.pcs[0].0 = 16;
    });
    assert_fixture("h2p010_illegal_pc", &cm, Code::IllegalPc);
}

#[test]
fn fixture_h2p011_pc_oversubscribed() {
    let cm = mutated("resnet50", |plan, _| {
        // find a fully-used PC and move another layer's slots onto it
        let cap = plan.device.chains_per_pc() as u64;
        let mut slots = vec![0u64; plan.device.hbm.total_pcs() as usize];
        for l in &plan.layers {
            for &(pc, s) in &l.pcs {
                slots[pc as usize] += s as u64;
            }
        }
        let full = slots
            .iter()
            .position(|&s| s == cap)
            .expect("resnet50 fills at least one pseudo-channel") as u32;
        let entry = plan
            .layers
            .iter_mut()
            .flat_map(|l| l.pcs.iter_mut())
            .find(|e| e.0 != full)
            .expect("a slot on another channel exists");
        entry.0 = full;
    });
    assert_fixture("h2p011_pc_oversubscribed", &cm, Code::PcOversubscribed);
}

#[test]
fn fixture_h2p012_pc_slot_mismatch() {
    let cm = mutated("resnet50", |plan, _| {
        let l = plan
            .layers
            .iter_mut()
            .find(|l| !l.pcs.is_empty())
            .expect("resnet50 offloads layers");
        l.pcs[0].1 -= 1;
    });
    assert_fixture("h2p012_pc_slot_mismatch", &cm, Code::PcSlotMismatch);
}

#[test]
fn fixture_h2p020_bandwidth_infeasible() {
    // BL2 derates reads to 0.44: a full pseudo-channel demands 240
    // bits/core-cycle against ~150 supplied. A fresh compile is otherwise
    // self-consistent, so the bandwidth warning is the only finding.
    let cm = Session::builder().model("resnet50").fixed_burst(2).compile().unwrap();
    assert_fixture("h2p020_bandwidth_infeasible", &cm, Code::BandwidthInfeasible);
    let report = check_artifact(&cm);
    assert_eq!(report.diagnostics[0].severity, Severity::Warn);
    assert!(report.denies(Severity::Warn) && !report.denies(Severity::Error));
}

#[test]
fn fixture_h2p021_burst_policy_mismatch() {
    // options pin Fixed(8) but the plan claims BL16; every derived scalar
    // is re-canonicalized at BL16 so only the policy contradiction fires
    let cm = recanonicalize(mutated("resnet50", |plan, _| {
        plan.options.burst_length = BurstLengthPolicy::Fixed(8);
        plan.burst_len = 16;
    }));
    assert_fixture("h2p021_burst_policy_mismatch", &cm, Code::BurstPolicyMismatch);
}

// --------------------------------------- family 3: structural deadlock

/// Three convolutions whose single chains share one pseudo-channel: the
/// minimal Fig. 5 topology.
fn rv_triple(flow: FlowControl) -> CompiledModel {
    let mut n = Network::new("rv-triple", Shape::new(16, 16, 16));
    let conv = OpKind::Conv { kind: ConvKind::Standard, kh: 3, kw: 3, stride: 1, pad: 1, out_c: 16 };
    let a = n.add("c1", conv.clone(), &[0]).unwrap();
    let b = n.add("c2", conv.clone(), &[a]).unwrap();
    n.add("c3", conv, &[b]).unwrap();
    Session::builder()
        .network(n)
        .options(CompilerOptions {
            all_hbm: true,
            burst_length: BurstLengthPolicy::Fixed(8),
            flow_control: flow,
            max_chains_per_layer: 1,
            ..CompilerOptions::default()
        })
        .compile()
        .unwrap()
}

#[test]
fn fixture_h2p030_ready_valid_deadlock() {
    let cm = rv_triple(FlowControl::ReadyValid);
    match analyze_plan(cm.plan()) {
        DeadlockVerdict::Hazard { layers, capacity_words, required_words, .. } => {
            assert_eq!(layers.len(), 3, "all three convs share the channel");
            assert!(required_words > capacity_words);
        }
        v => panic!("expected a hazard, got {v:?}"),
    }
    assert_fixture("h2p030_ready_valid_deadlock", &cm, Code::ReadyValidDeadlock);
    // the same plan under credit flow control is cycle-free
    let fixed = rv_triple(FlowControl::Credit);
    assert_eq!(analyze_plan(fixed.plan()), DeadlockVerdict::CreditCycleFree);
    assert!(check_artifact(&fixed).is_clean());
}

#[test]
fn static_deadlock_rule_agrees_with_fig5_simulation() {
    // ready/valid: the static rule flags the scenario AND the cycle-level
    // Fig. 5 reproduction actually deadlocks
    let cfg = ScenarioConfig::default();
    assert!(scenario_has_hazard(FlowControl::ReadyValid, &cfg));
    assert!(matches!(
        run_shared_pc_pipeline(FlowControl::ReadyValid, &cfg),
        PipelineOutcome::Deadlocked { .. }
    ));

    // credit: the static rule proves it cycle-free AND the sim completes
    assert!(!scenario_has_hazard(FlowControl::Credit, &cfg));
    assert!(matches!(
        run_shared_pc_pipeline(FlowControl::Credit, &cfg),
        PipelineOutcome::Completed { .. }
    ));

    // ready/valid with burst FIFOs deep enough for whole streams: the
    // conservative rule stands down, and the sim indeed completes
    let deep = ScenarioConfig { burst_fifo_capacity: 4096, ..ScenarioConfig::default() };
    assert!(!scenario_has_hazard(FlowControl::ReadyValid, &deep));
    assert!(matches!(
        run_shared_pc_pipeline(FlowControl::ReadyValid, &deep),
        PipelineOutcome::Completed { .. }
    ));
}

// ------------------------------------------------ family 4: FIFO depth

#[test]
fn fixture_h2p040_fifo_depth_shortfall() {
    // 128 words < the 201-word BL8 bound (§IV-A sized 512 for this)
    let cm = recanonicalize(mutated("resnet50", |plan, _| {
        plan.options.last_stage_fifo_depth = 128;
    }));
    assert_fixture("h2p040_fifo_depth_shortfall", &cm, Code::FifoDepthShortfall);
    let d = &check_artifact(&cm).diagnostics[0];
    assert_eq!(d.severity, Severity::Warn);
    assert!(d.hint.as_deref().unwrap_or("").contains("256"), "next pow2 over the bound");
}

// -------------------------------------- family 5: internal consistency

#[test]
fn fixture_h2p050_estimate_tamper() {
    let cm = mutated("resnet50", |plan, _| {
        plan.est_throughput *= 2.0;
    });
    assert_fixture("h2p050_estimate_tamper", &cm, Code::EstimateMismatch);
}

#[test]
fn fixture_h2p051_bottleneck_tamper() {
    let cm = mutated("resnet50", |plan, _| {
        plan.bottleneck_cycles += 1;
    });
    assert_fixture("h2p051_bottleneck_tamper", &cm, Code::BottleneckMismatch);
}

#[test]
fn fixture_h2p052_free_bw_tamper() {
    let cm = mutated("resnet50", |plan, _| {
        plan.free_bw_slots += 1;
    });
    assert_fixture("h2p052_free_bw_tamper", &cm, Code::FreeBwMismatch);
}

#[test]
fn fixture_h2p053_efficiency_tamper() {
    let cm = mutated("resnet50", |plan, _| {
        plan.hbm_read_efficiency = 0.5;
    });
    assert_fixture("h2p053_efficiency_tamper", &cm, Code::EfficiencyMismatch);
}

#[test]
fn fixture_h2p054_options_hash_tamper() {
    let cm = mutated("resnet50", |_, prov| {
        prov.options_hash ^= 1;
    });
    assert_fixture("h2p054_options_hash_tamper", &cm, Code::OptionsHashMismatch);
    assert!(CompiledModel::from_json(&cm.to_json()).is_err(), "integrity gate");
}

// ------------------------------------------------ family 6: fleet rules

#[test]
fn clean_partition_verifies_clean() {
    let net = zoo::vgg16();
    let o = CompilerOptions::default();
    let d = h2pipe::config::DeviceConfig::stratix10_nx2100();
    let pp = partition(&net, &d, &o, &PartitionOptions { shards: Some(2), max_shards: 2 })
        .unwrap();
    let report = check_partition(&net, &pp);
    assert!(report.is_clean(), "{}", report.render());
}

#[test]
fn tampered_cut_trips_h2p060() {
    let net = zoo::resnet18();
    let o = CompilerOptions::default();
    let d = h2pipe::config::DeviceConfig::stratix10_nx2100();
    let mut pp = partition_at(&net, &d, &o, &[6]).unwrap();
    // shift the boundary inside the residual block, keeping coverage
    // contiguous so only cut legality fires
    pp.shards[0].last_layer = 3;
    pp.shards[1].first_layer = 4;
    let report = check_partition(&net, &pp);
    assert_codes(&report, &[Code::IllegalCut], "tampered cut");
}

#[test]
fn shard_gap_trips_h2p061() {
    let net = zoo::resnet18();
    let o = CompilerOptions::default();
    let d = h2pipe::config::DeviceConfig::stratix10_nx2100();
    let mut pp = partition_at(&net, &d, &o, &[6]).unwrap();
    pp.network = "someone-elses-network".to_string();
    let report = check_partition(&net, &pp);
    assert_codes(&report, &[Code::ShardCoverage], "partition identity");
}

#[test]
fn weightless_shard_trips_h2p062() {
    let net = zoo::resnet18();
    let o = CompilerOptions::default();
    let d = h2pipe::config::DeviceConfig::stratix10_nx2100();
    let mut pp = partition_at(&net, &d, &o, &[6]).unwrap();
    // swap in a shard net holding only a pooling layer
    let mut hollow = Network::new(&pp.shards[1].net.name, pp.shards[1].net.input_shape());
    hollow.add("pool", OpKind::MaxPool { k: 2, stride: 2, pad: 0 }, &[0]).unwrap();
    pp.shards[1].net = hollow;
    let report = check_partition(&net, &pp);
    assert_codes(&report, &[Code::WeightlessShard], "hollow shard");
}

// ------------------------------------------------- registry cross-check

#[test]
fn design_md_registry_lists_every_code() {
    let doc = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../DESIGN.md");
    let text = std::fs::read_to_string(&doc).expect("DESIGN.md at the repo root");
    for code in Code::ALL {
        assert!(
            text.contains(code.as_str()),
            "DESIGN.md diagnostics registry is missing {}",
            code.as_str()
        );
    }
}
