//! Integration: the observability subsystem end to end.
//!
//! The three properties the issue pins:
//!
//! 1. **Conservation** — the flight recorder stores window *deltas* of
//!    cumulative counters, so the sum of every engine track's windows
//!    must equal that engine's end-of-run `SimReport` aggregate exactly
//!    (no sampling loss, no double counting).
//! 2. **Non-perturbation** — attaching a probe must not change the
//!    simulation: a probed run reports byte-identical results to a plain
//!    run of the same plan.
//! 3. **Determinism** — the cycle-domain Chrome trace of a persisted
//!    plan artifact is byte-stable across runs and always parses with
//!    the repo's strict JSON parser.

use h2pipe::cluster::{partition, FleetConfig, FleetSim, PartitionOptions};
use h2pipe::obs::Recorder;
use h2pipe::obs::trace::chrome_trace;
use h2pipe::session::{CompiledModel, DeploymentTarget, ServeOptions, Session, TraceOptions};
use h2pipe::sim::pipeline::SimConfig;
use h2pipe::util::Json;

fn quick() -> SimConfig {
    SimConfig { images: 3, warmup_images: 1, ..SimConfig::default() }
}

fn tmp_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("h2pipe-obs-{tag}-{}.json", std::process::id()))
}

#[test]
fn recorder_windows_conserve_sim_report_aggregates() {
    // The acceptance model: ResNet-50 hybrid (HBM layers + on-chip
    // layers + pass-through engines all present).
    let cm = Session::builder().model("resnet50").compile().unwrap();
    let mut rec = Recorder::new(2048);
    let rep = cm.simulate_probed(&quick(), &mut rec).unwrap();

    assert_eq!(rec.engines.len(), rep.engine_stats.len(), "one track per engine");
    for (i, s) in rep.engine_stats.iter().enumerate() {
        let tot = rec.engine_totals(i).unwrap_or_else(|| panic!("engine {i} has no track"));
        assert_eq!(tot.active, s.active, "engine {i} ({}) active", s.name);
        assert_eq!(tot.input_starved, s.input_starved, "engine {i} ({}) starved", s.name);
        assert_eq!(tot.output_blocked, s.output_blocked, "engine {i} ({}) blocked", s.name);
        assert_eq!(tot.weight_frozen, s.weight_frozen, "engine {i} ({}) frozen", s.name);
        assert_eq!(rec.engines[&i].name, s.name, "track names follow the plan");
    }

    // HBM side: the recorder saw traffic on some PC iff the run used HBM
    // weights, and the profile block reflects the recording.
    assert!(rec.pc_data_cycles_total() > 0, "ResNet-50 streams weights from HBM");
    assert!(!rec.bursts.is_empty(), "burst events must be recorded");
    let profile = rec.profile();
    assert!(profile.get("bottlenecks").and_then(Json::as_arr).map_or(false, |b| !b.is_empty()));
    let fill = profile.get("max_fifo_fill").and_then(Json::as_f64).unwrap();
    assert!(fill > 0.0 && fill <= 1.0, "peak FIFO fill {fill} must be within compiled depth");
}

#[test]
fn probe_does_not_perturb_the_simulation() {
    let cm = Session::builder().model("resnet18").compile().unwrap();
    let plain = cm.simulate(&quick()).unwrap();
    let mut rec = Recorder::new(512);
    let probed = cm.simulate_probed(&quick(), &mut rec).unwrap();
    assert_eq!(
        probed.to_json().to_string(),
        plain.to_json().to_string(),
        "a probed run must report byte-identical results"
    );
}

#[test]
fn trace_of_a_plan_artifact_is_byte_stable_and_strictly_parseable() {
    let cm = Session::builder().model("resnet18").compile().unwrap();
    let path = tmp_path("artifact");
    cm.save(&path).unwrap();
    let loaded = CompiledModel::load(&path).unwrap();
    let d = &loaded.plan().device;

    let run = |cm: &CompiledModel| {
        let mut rec = Recorder::new(1024);
        cm.simulate_probed(&quick(), &mut rec).unwrap();
        chrome_trace(&rec, d.core_mhz, d.hbm.controller_mhz).to_string()
    };
    let a = run(&loaded);
    let b = run(&loaded);
    assert_eq!(a, b, "two runs of the same artifact must render identical traces");

    let j = Json::parse(&a).expect("trace must satisfy the strict parser");
    let ev = j.get("traceEvents").and_then(Json::as_arr).unwrap();

    // Every engine renders at least one stall/active span on its thread.
    let n_engines = loaded.plan().layers.len();
    for i in 0..n_engines {
        let tid = i as u64 + 1;
        assert!(
            ev.iter().any(|e| {
                e.get("ph").and_then(Json::as_str) == Some("X")
                    && e.get("pid").and_then(Json::as_u64) == Some(1)
                    && e.get("tid").and_then(Json::as_u64) == Some(tid)
            }),
            "engine {i} has no span in the trace"
        );
    }
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn fleet_probe_rebases_shard_tracks_and_samples_links() {
    let cm = Session::builder().model("resnet18").compile().unwrap();
    let plan = cm.plan();
    let pp = partition(
        cm.network(),
        &plan.device,
        &plan.options,
        &PartitionOptions { shards: Some(2), max_shards: 2 },
    )
    .unwrap();
    let fleet = FleetSim::new(&pp).unwrap();
    let mut rec = Recorder::new(1024);
    let rep = fleet
        .run_probed(&FleetConfig { images: 3, warmup_images: 1, ..Default::default() }, &mut rec)
        .unwrap();

    // Tracks from both shards, re-based to fleet-global indices with
    // shard-prefixed names.
    let total_engines: usize = pp.shards.iter().map(|s| s.plan.layers.len()).sum();
    assert_eq!(rec.engines.len(), total_engines, "every shard engine has a track");
    assert!(rec.engines.values().any(|t| t.name.starts_with("s0/")));
    assert!(rec.engines.values().any(|t| t.name.starts_with("s1/")));

    // The inter-shard link was sampled and its window sums conserve the
    // lines the fleet report counted.
    assert_eq!(rec.links.len(), 1, "one link between two shards");
    let link_lines: u64 = rec.links[&0].windows.iter().map(|w| w.lines).sum();
    assert_eq!(link_lines, rep.links[0].lines, "link window sums equal the fleet aggregate");
}

#[test]
fn traced_serve_deployment_writes_request_spans_and_exposes_metrics() {
    let cm = Session::builder().model("resnet18").compile().unwrap();
    let path = tmp_path("serve-trace");
    let rep = cm
        .deploy(DeploymentTarget::Serve(ServeOptions {
            serve_model: "cifarnet".to_string(),
            requests: 6,
            batch: 2,
            replicas: 2,
            // port 0: bind any free port; exercises the exposition
            // endpoint lifecycle (start, serve, stop before shutdown).
            metrics_port: Some(0),
            ..ServeOptions::default()
        }))
        .with_trace(TraceOptions {
            json_path: Some(path.display().to_string()),
            csv_path: None,
            window: 4096,
        })
        .run()
        .unwrap();
    assert_eq!(rep.target, "serve");
    assert_eq!(rep.detail.get("ok").and_then(Json::as_u64), Some(6));

    let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    let ev = j.get("traceEvents").and_then(Json::as_arr).unwrap();
    let spans = ev
        .iter()
        .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
        .count();
    assert_eq!(spans, 6, "one request span per completed request");
    std::fs::remove_file(&path).unwrap();
}
