//! Property-based integration tests (via the in-repo testkit): flow
//! control, HBM scheduling legality, offload invariants, and simulator
//! conservation under randomized configurations.

use h2pipe::compiler::{algorithm1, compile, LayerStats, Parallelism};
use h2pipe::config::{BurstLengthPolicy, CompilerOptions, DeviceConfig};
use h2pipe::fabric::deadlock::ScenarioConfig;
use h2pipe::fabric::{run_shared_pc_pipeline, CreditCounter, FlowControl, PipelineOutcome, ScFifo};
use h2pipe::hbm::controller::{Dir, PcTuning, PseudoChannel, Request};
use h2pipe::hbm::CmdBus;
use h2pipe::nn::zoo;
use h2pipe::testkit::{check, Gen};

#[test]
fn prop_credit_conservation_under_random_traffic() {
    check("credit-conservation", 200, |g: &mut Gen| {
        let max = g.u32(1, 64);
        let mut c = CreditCounter::new(max);
        let mut out = 0u32;
        for _ in 0..g.usize(10, 300) {
            if g.bool(0.5) {
                let n = g.u32(1, 8);
                if c.acquire(n) {
                    out += n;
                }
            } else if out > 0 {
                let n = g.u32(1, 8).min(out);
                c.release(n);
                out -= n;
            }
            if c.available() + out != max {
                return Err(format!("conservation broken: {} + {out} != {max}", c.available()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_fifo_never_overflows_or_loses_order() {
    check("fifo-order", 100, |g: &mut Gen| {
        let cap = g.usize(1, 64);
        let mut f = ScFifo::with_capacity(cap);
        let mut next_in = 0u64;
        let mut next_out = 0u64;
        for _ in 0..g.usize(10, 500) {
            if g.bool(0.6) {
                if f.push(next_in) {
                    next_in += 1;
                }
            } else if let Some(v) = f.pop() {
                if v != next_out {
                    return Err(format!("order broken: {v} != {next_out}"));
                }
                next_out += 1;
            }
            if f.len() > cap {
                return Err("overflow".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_hbm_every_accepted_request_completes_once() {
    let d = DeviceConfig::stratix10_nx2100();
    check("hbm-completion", 25, |g: &mut Gen| {
        let mut pc = PseudoChannel::new(
            &d.hbm,
            &d.hbm_timing,
            PcTuning { outstanding_beats: g.u32(32, 256), lookahead: g.usize(1, 12) },
        );
        let bursts = [1u32, 2, 4, 8, 16, 32];
        let mut accepted = std::collections::HashSet::new();
        let mut completed = std::collections::HashSet::new();
        let mut id = 0u64;
        for _ in 0..g.usize(2_000, 10_000) {
            let bl = *g.choose(&bursts);
            if g.bool(0.7) && pc.can_accept(bl) {
                let dir = if g.bool(0.3) { Dir::Write } else { Dir::Read };
                let addr = g.u64(0, (1 << 26) - 1) & !31;
                pc.push(Request { id, dir, addr, burst: bl });
                accepted.insert(id);
                id += 1;
            }
            let mut bus = CmdBus::new();
            pc.tick(&mut bus);
            for c in pc.drain_completions() {
                if !completed.insert(c.id) {
                    return Err(format!("request {} completed twice", c.id));
                }
                if c.done_cycle <= c.accept_cycle {
                    return Err("non-causal completion".into());
                }
            }
        }
        let mut guard = 0;
        while !pc.is_idle() {
            let mut bus = CmdBus::new();
            pc.tick(&mut bus);
            for c in pc.drain_completions() {
                completed.insert(c.id);
            }
            guard += 1;
            if guard > 2_000_000 {
                return Err("drain did not converge".into());
            }
        }
        if accepted != completed {
            return Err(format!(
                "{} accepted vs {} completed",
                accepted.len(),
                completed.len()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_algorithm1_never_oversubscribes_bandwidth() {
    let nets = [zoo::resnet18(), zoo::resnet50(), zoo::vgg16(), zoo::mobilenet_v2()];
    let o = CompilerOptions::default();
    check("alg1-bandwidth", 60, |g: &mut Gen| {
        let net = g.choose(&nets);
        let stats: Vec<LayerStats> =
            net.layers().iter().map(|l| LayerStats::from_layer(l, &o)).collect();
        let par: Vec<Parallelism> = stats
            .iter()
            .map(|_| Parallelism { p_i: g.u32(1, 4), p_o: g.u32(1, 8) })
            .collect();
        let n_pc = g.u64(1, 31);
        let force = g.bool(0.5);
        let plan = algorithm1(&stats, &par, n_pc, 3, force, |_| false);
        let used: u64 = stats
            .iter()
            .zip(plan.offload.iter())
            .zip(par.iter())
            .filter(|((_, &off), _)| off)
            .map(|((_, _), p)| p.chains() as u64)
            .sum();
        if used + plan.free_bw > n_pc * 3 || used > n_pc * 3 {
            return Err(format!("oversubscribed: used {used} of {}", n_pc * 3));
        }
        Ok(())
    });
}

#[test]
fn prop_credit_protocol_never_deadlocks() {
    check("credit-no-deadlock", 40, |g: &mut Gen| {
        let cfg = ScenarioConfig {
            weights_per_item: [g.u32(1, 8), g.u32(1, 8), g.u32(1, 8)],
            burst_fifo_capacity: g.usize(1, 16),
            dcfifo_capacity: g.usize(4, 32),
            act_queue_capacity: g.usize(1, 6),
            items: 40,
            hbm_latency: g.u64(1, 60),
            watchdog: 20_000,
        };
        match run_shared_pc_pipeline(FlowControl::Credit, &cfg) {
            PipelineOutcome::Completed { .. } => Ok(()),
            PipelineOutcome::Deadlocked { .. } => Err(format!("deadlocked: {cfg:?}")),
        }
    });
}

#[test]
fn prop_compiled_plans_fit_device_for_random_options() {
    let d = DeviceConfig::stratix10_nx2100();
    let nets = [zoo::resnet18(), zoo::resnet50(), zoo::vgg16()];
    check("plan-fits", 25, |g: &mut Gen| {
        let net = g.choose(&nets);
        let mut o = CompilerOptions::default();
        o.all_hbm = g.bool(0.3);
        o.burst_length = BurstLengthPolicy::Fixed(*g.choose(&[8u32, 16, 32]));
        o.write_path_bits = g.u32(8, 256);
        o.max_chains_per_layer = g.u32(4, 48);
        let plan = compile(net, &d, &o).map_err(|e| format!("{e:#}"))?;
        if plan.usage.m20k > d.m20k_blocks as u64 {
            return Err(format!("M20K overflow {}", plan.usage.m20k));
        }
        if plan.usage.tensor_blocks > d.tensor_blocks as u64 {
            return Err("TB overflow".into());
        }
        // every offloaded layer within per-PC slot capacity
        let mut per_pc = std::collections::HashMap::new();
        for l in plan.hbm_layers() {
            let slots: u32 = l.pcs.iter().map(|&(_, c)| c).sum();
            if slots != l.par.chains() {
                return Err(format!("{}: slots {slots} != chains {}", l.stats.name, l.par.chains()));
            }
            for &(pc, c) in &l.pcs {
                *per_pc.entry(pc).or_insert(0u32) += c;
            }
        }
        for (pc, used) in per_pc {
            if used > 3 {
                return Err(format!("PC{pc} oversubscribed: {used}"));
            }
            if d.excluded_pcs.contains(&pc) {
                return Err(format!("excluded PC{pc} used"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_failure_injection_watchdog_catches_starved_pipeline() {
    // Failure injection: a prefetcher that can never issue (zero-capacity
    // burst FIFOs are not constructible, so use weights_per_item with a
    // DCFIFO too small to ever hold a full round) must be detected as a
    // deadlock by the watchdog rather than hanging.
    let cfg = ScenarioConfig {
        weights_per_item: [8, 8, 8],
        burst_fifo_capacity: 1,
        dcfifo_capacity: 1,
        act_queue_capacity: 1,
        items: 1000,
        hbm_latency: 4000, // latency far beyond the watchdog
        watchdog: 2000,
        ..ScenarioConfig::default()
    };
    let out = run_shared_pc_pipeline(FlowControl::ReadyValid, &cfg);
    // either it (slowly) completes or the watchdog fires — it must return
    match out {
        PipelineOutcome::Completed { .. } | PipelineOutcome::Deadlocked { .. } => {}
    }
}
