//! Fig. 3a — HBM pseudo-channel read/write efficiency vs burst length.
//!
//! Paper procedure (§III-A): saturating random-address traffic, 10,000
//! write transactions then 10,000 reads, per burst length. Two series are
//! produced: the "hardware" calibration (default controller tuning) and a
//! "simulation-model" calibration with an idealized deeper reorder window
//! — mirroring the paper's observation that the vendor simulation model
//! is optimistic at small burst lengths but matches hardware at BL >= 8.

use h2pipe::bench_harness::Bench;
use h2pipe::config::DeviceConfig;
use h2pipe::hbm::controller::PcTuning;
use h2pipe::hbm::{AddressPattern, TrafficConfig, TrafficGen};
use h2pipe::util::Json;

fn main() {
    let mut b = Bench::new("fig3a_hbm_efficiency");
    let device = DeviceConfig::stratix10_nx2100();
    let gen = TrafficGen::new(&device);
    let bursts = [1u32, 2, 4, 8, 16, 32];
    // paper procedure is 10k transactions/phase; smoke runs use 400
    let txns = h2pipe::bench_harness::scaled(10_000, 400);

    let mut rows = Vec::new();
    let mut series = Json::Arr(vec![]);
    for &bl in &bursts {
        // "hardware" calibration
        let mut hw_cfg = TrafficConfig::new(AddressPattern::Random, bl);
        hw_cfg.transactions = txns;
        let hw = gen.run(&hw_cfg);
        // "simulation model" calibration: deeper reorder window is the
        // main idealization of the vendor model at small bursts
        let mut sim_cfg = TrafficConfig::new(AddressPattern::Random, bl);
        sim_cfg.transactions = txns;
        sim_cfg.tuning = PcTuning { outstanding_beats: 256, lookahead: 16 };
        let sim = gen.run(&sim_cfg);
        rows.push(vec![
            bl.to_string(),
            format!("{:.3}", hw.read_efficiency),
            format!("{:.3}", hw.write_efficiency),
            format!("{:.3}", sim.read_efficiency),
            format!("{:.3}", sim.write_efficiency),
        ]);
        let mut o = Json::obj();
        o.set("burst", bl)
            .set("hw_read_eff", hw.read_efficiency)
            .set("hw_write_eff", hw.write_efficiency)
            .set("sim_read_eff", sim.read_efficiency)
            .set("sim_write_eff", sim.write_efficiency);
        series.push(o);
    }
    b.table(&["BL", "hw read", "hw write", "sim read", "sim write"], &rows);
    b.record("series", series);

    // paper reference points for EXPERIMENTS.md diffing
    let mut paper = Json::obj();
    paper
        .set("read_eff_bl8", 0.83)
        .set("read_eff_bl32", 0.93)
        .set("write_vs_read_gap_pp", 15.0)
        .set("bl_lt4_ratio", 0.55);
    b.record("paper_reference", paper);

    // wall-time of a full characterization run (the "instrument cost")
    let iters = h2pipe::bench_harness::scaled(3, 1) as u32;
    b.time("characterize_bl8_10k_txns", 0, iters, || {
        let mut cfg = TrafficConfig::new(AddressPattern::Random, 8);
        cfg.transactions = txns;
        let _ = gen.run(&cfg);
    });
    b.finish();
}
