//! Fig. 6 — hardware all-HBM and hybrid throughput vs the all-HBM
//! theoretical upper bound (Eq. 2 over 279 GB/s) and the unlimited-HBM-
//! bandwidth bound, for ResNet-18/50 and VGG-16.
//!
//! Paper claims to check: all-HBM measured lands at 68–78% of its bound;
//! hybrid ResNet-18 nearly doubles the all-HBM bound; ResNet-50 / VGG-16
//! would gain ~2.3x / ~2.1x more with unlimited stacks.

use h2pipe::analysis::bounds::bounds_report;
use h2pipe::analysis::{fig6_json, H2pipeResult};
use h2pipe::bench_harness::Bench;
use h2pipe::compiler::compile;
use h2pipe::config::{CompilerOptions, DeviceConfig};
use h2pipe::nn::zoo;
use h2pipe::sim::pipeline::{simulate, SimConfig};

fn main() {
    let mut b = Bench::new("fig6_bounds");
    let device = DeviceConfig::stratix10_nx2100();
    let cfg = SimConfig {
        images: h2pipe::bench_harness::scaled(5, 2),
        warmup_images: h2pipe::bench_harness::scaled(2, 1),
        ..SimConfig::default()
    };
    let opts = CompilerOptions::default();

    let paper: &[(&str, f64, f64)] =
        &[("ResNet-18", 1811.0, 4174.0), ("ResNet-50", 748.0, 1004.0), ("VGG-16", 430.0, 545.0)];

    let mut rows = Vec::new();
    let mut results = Vec::new();
    for net in zoo::eval_models() {
        let hybrid_plan = compile(&net, &device, &opts).unwrap();
        let hybrid = simulate(&net, &hybrid_plan, &cfg).unwrap();
        let mut o2 = opts.clone();
        o2.all_hbm = true;
        let all_plan = compile(&net, &device, &o2).unwrap();
        let all = simulate(&net, &all_plan, &cfg).unwrap();
        let bounds = bounds_report(&net, &device, &opts).unwrap();
        let (pa, ph) = paper
            .iter()
            .find(|(n, _, _)| *n == net.name)
            .map(|(_, a, h)| (*a, *h))
            .unwrap();

        rows.push(vec![
            net.name.clone(),
            format!("{:.0}", all.throughput),
            format!("{pa:.0}"),
            format!("{:.0}", hybrid.throughput),
            format!("{ph:.0}"),
            format!("{:.0}", bounds.all_hbm_bound),
            format!("{:.0}", bounds.unlimited_bw_bound),
            format!("{:.0}%", 100.0 * all.throughput / bounds.all_hbm_bound),
        ]);
        results.push((
            H2pipeResult {
                network: net.name.clone(),
                all_hbm_throughput: all.throughput,
                hybrid_throughput: hybrid.throughput,
                latency_ms: hybrid.latency * 1e3,
                logic_util: hybrid_plan.usage.alm_frac(&device),
                bram_util: hybrid_plan.usage.m20k_frac(&device),
                dsp_util: hybrid_plan.usage.tb_frac(&device),
                freq_mhz: device.core_mhz,
            },
            bounds,
        ));
    }
    b.table(
        &[
            "Model",
            "allHBM",
            "paper",
            "hybrid",
            "paper",
            "bound(allHBM)",
            "bound(unl.BW)",
            "hw/bound",
        ],
        &rows,
    );
    b.record("fig6", fig6_json(&results));
    b.finish();
}
