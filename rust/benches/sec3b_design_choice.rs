//! §III-B — the design-choice analysis behind H2PIPE: offload weights,
//! not activations, and stay layer-pipelined rather than batching.
//!
//! Regenerates: (a) the paper's MobileNetV2 arithmetic ("53 x 0.4 us =
//! 21 us >= 11% of 190 us") extended to every model; (b) the §II-B
//! fpgaConvNet-style time-multiplexed baseline showing how much batch it
//! takes to approach dataflow throughput — and what it costs in latency.

use h2pipe::analysis::{activation_offload_penalty, fpgaconvnet_style};
use h2pipe::bench_harness::Bench;
use h2pipe::compiler::compile;
use h2pipe::config::{CompilerOptions, DeviceConfig};
use h2pipe::nn::zoo;
use h2pipe::sim::pipeline::{simulate, SimConfig};
use h2pipe::util::Json;

fn main() {
    let mut b = Bench::new("sec3b_design_choice");
    let device = DeviceConfig::stratix10_nx2100();
    let opts = CompilerOptions::default();
    let cfg = SimConfig {
        images: h2pipe::bench_harness::scaled(4, 2),
        warmup_images: 1,
        ..SimConfig::default()
    };

    // (a) activation-offload penalty, against our own simulated latency
    println!("--- offloading activations instead of weights (saturated 400 ns/read) ---");
    let mut rows = Vec::new();
    let mut series = Json::Arr(vec![]);
    for net in zoo::table1_models() {
        let plan = compile(&net, &device, &opts).unwrap();
        let base = simulate(&net, &plan, &cfg).unwrap().latency;
        let r = activation_offload_penalty(&net, &opts, 400.0, base);
        rows.push(vec![
            net.name.clone(),
            r.layers.to_string(),
            format!("{:.1}", r.added_latency * 1e6),
            format!("{:.1}", base * 1e6),
            format!("+{:.1}%", 100.0 * r.increase()),
        ]);
        let mut o = Json::obj();
        o.set("model", net.name.as_str())
            .set("weight_layers", r.layers)
            .set("added_us", r.added_latency * 1e6)
            .set("base_latency_us", base * 1e6)
            .set("increase_frac", r.increase());
        series.push(o);
    }
    b.table(&["Model", "layers", "added(us)", "base(us)", "increase"], &rows);
    b.record("activation_offload", series);
    // paper's exact arithmetic as a pinned reference
    let paper = activation_offload_penalty(&zoo::mobilenet_v2(), &opts, 400.0, 190e-6);
    println!(
        "paper check: MobileNetV2 {} layers x 0.4us = {:.0}us on 190us -> +{:.0}% (paper: >=11%)",
        paper.layers,
        paper.added_latency * 1e6,
        100.0 * paper.increase()
    );
    assert!(paper.increase() >= 0.11);

    // (b) fpgaConvNet-style batch baseline vs H2PIPE batch-1
    println!("\n--- fpgaConvNet-style layer-subset baseline (VGG-16) ---");
    let net = zoo::vgg16();
    let plan = compile(&net, &device, &opts).unwrap();
    let h2 = simulate(&net, &plan, &cfg).unwrap();
    let mut brows = Vec::new();
    let mut bseries = Json::Arr(vec![]);
    for batch in [1u64, 4, 16, 64, 256] {
        let r = fpgaconvnet_style(&net, &device, &opts, batch);
        brows.push(vec![
            batch.to_string(),
            r.subsets.to_string(),
            format!("{:.1}", r.throughput),
            format!("{:.1}", r.latency * 1e3),
        ]);
        let mut o = Json::obj();
        o.set("batch", batch)
            .set("subsets", r.subsets)
            .set("im_s", r.throughput)
            .set("latency_ms", r.latency * 1e3);
        bseries.push(o);
    }
    b.table(&["batch", "subsets", "im/s", "latency(ms)"], &brows);
    println!(
        "H2PIPE batch-1 on the same device: {:.0} im/s at {:.2} ms — the always-resident \
         pipeline needs no batch to reach its peak.",
        h2.throughput,
        h2.latency * 1e3
    );
    b.record("fpgaconvnet_baseline", bseries);
    b.record("h2pipe_batch1_im_s", h2.throughput);
    b.finish();
}
