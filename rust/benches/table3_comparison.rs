//! Table III — comparison against prior FPGA CNN accelerators.
//!
//! Prior rows are literature data (exactly as in the paper); the H2PIPE
//! rows are measured by our cycle simulator; the speedup lines reproduce
//! the paper's 19.4x / 5.1x / 10.5x headline arithmetic against the best
//! comparable-precision prior work. An in-simulator PE-style baseline is
//! reported alongside, so both architectural paradigms of §I come from
//! executable models, not citations alone.

use h2pipe::analysis::{
    pe_baseline_throughput, speedup_vs_best_prior, table3_text, H2pipeResult,
};
use h2pipe::bench_harness::Bench;
use h2pipe::compiler::compile;
use h2pipe::config::{CompilerOptions, DeviceConfig};
use h2pipe::nn::zoo;
use h2pipe::sim::pipeline::{simulate, SimConfig};
use h2pipe::util::Json;

fn main() {
    let mut b = Bench::new("table3_comparison");
    let device = DeviceConfig::stratix10_nx2100();
    let opts = CompilerOptions::default();
    let cfg = SimConfig {
        images: h2pipe::bench_harness::scaled(5, 2),
        warmup_images: h2pipe::bench_harness::scaled(2, 1),
        ..SimConfig::default()
    };

    let mut ours = Vec::new();
    let mut macs = Vec::new();
    let mut series = Json::Arr(vec![]);
    for net in zoo::eval_models() {
        let plan = compile(&net, &device, &opts).unwrap();
        let rep = simulate(&net, &plan, &cfg).unwrap();
        macs.push((net.name.clone(), net.total_macs()));
        let pe = pe_baseline_throughput(&net, &device, &opts);
        let speedup = speedup_vs_best_prior(&net.name, rep.throughput).unwrap_or(f64::NAN);
        let mut o = Json::obj();
        o.set("network", net.name.as_str())
            .set("h2pipe_im_s", rep.throughput)
            .set("h2pipe_latency_ms", rep.latency * 1e3)
            .set("pe_baseline_im_s", pe)
            .set("speedup_vs_best_prior", speedup)
            .set("logic_util", plan.usage.alm_frac(&device))
            .set("bram_util", plan.usage.m20k_frac(&device))
            .set("dsp_util", plan.usage.tb_frac(&device));
        series.push(o);
        ours.push(H2pipeResult {
            network: net.name.clone(),
            all_hbm_throughput: 0.0,
            hybrid_throughput: rep.throughput,
            latency_ms: rep.latency * 1e3,
            logic_util: plan.usage.alm_frac(&device),
            bram_util: plan.usage.m20k_frac(&device),
            dsp_util: plan.usage.tb_frac(&device),
            freq_mhz: device.core_mhz,
        });
        println!(
            "{:<10}  H2PIPE {:>6.0} im/s   PE-baseline {:>5.0} im/s   speedup vs best prior {:>5.1}x",
            net.name, rep.throughput, pe, speedup
        );
    }
    print!("{}", table3_text(&ours, &macs));
    b.record("rows", series);

    let mut paper = Json::obj();
    paper
        .set("speedup_resnet18", 19.4)
        .set("speedup_resnet50", 5.1)
        .set("speedup_vgg16", 10.5);
    b.record("paper_reference", paper);
    b.finish();
}
