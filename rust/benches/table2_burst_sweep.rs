//! Table II — hybrid-memory throughput vs HBM burst length.
//!
//! Paper: ResNet-18 is flat from BL8 to BL16 (its bottleneck layer keeps
//! weights on chip), while ResNet-50 gains ~2% from BL8 to BL32 at the
//! cost of logic (its bottleneck streams from HBM).

use h2pipe::bench_harness::Bench;
use h2pipe::compiler::compile;
use h2pipe::config::{BurstLengthPolicy, CompilerOptions, DeviceConfig};
use h2pipe::nn::zoo;
use h2pipe::sim::pipeline::{simulate, SimConfig};
use h2pipe::util::Json;

fn main() {
    let mut b = Bench::new("table2_burst_sweep");
    let device = DeviceConfig::stratix10_nx2100();
    let cfg = SimConfig {
        images: h2pipe::bench_harness::scaled(5, 2),
        warmup_images: h2pipe::bench_harness::scaled(2, 1),
        ..SimConfig::default()
    };

    // paper rows: (model, BL, logic util %, im/s)
    let paper: &[(&str, u32, f64)] = &[
        ("resnet18", 8, 4174.0),
        ("resnet18", 16, 4174.0),
        ("resnet50", 8, 984.0),
        ("resnet50", 16, 988.0),
        ("resnet50", 32, 1004.0),
    ];

    let mut rows = Vec::new();
    let mut series = Json::Arr(vec![]);
    for name in ["resnet18", "resnet50"] {
        let net = zoo::by_name(name).unwrap();
        let mut base: Option<f64> = None;
        for bl in [8u32, 16, 32] {
            let mut o = CompilerOptions::default();
            o.burst_length = BurstLengthPolicy::Fixed(bl);
            let plan = compile(&net, &device, &o).unwrap();
            let rep = simulate(&net, &plan, &cfg).unwrap();
            let logic = 100.0 * plan.usage.alm_frac(&device);
            let rel = base.map(|x| rep.throughput / x).unwrap_or(1.0);
            base.get_or_insert(rep.throughput);
            let paper_t = paper
                .iter()
                .find(|(n, pbl, _)| *n == name && *pbl == bl)
                .map(|(_, _, t)| *t);
            rows.push(vec![
                name.into(),
                bl.to_string(),
                format!("{logic:.0}%"),
                format!("{:.0}", rep.throughput),
                paper_t.map(|t| format!("{t:.0}")).unwrap_or_else(|| "-".into()),
                format!("{rel:.3}x"),
                format!("{:.4}", rep.freeze_fraction),
            ]);
            let mut jo = Json::obj();
            jo.set("model", name)
                .set("burst", bl)
                .set("logic_util", logic / 100.0)
                .set("im_s", rep.throughput)
                .set("paper_im_s", paper_t.unwrap_or(f64::NAN))
                .set("relative_to_bl8", rel)
                .set("freeze_fraction", rep.freeze_fraction)
                .set("bottleneck_on_hbm", rep.bottleneck_on_hbm);
            series.push(jo);
        }
    }
    b.table(
        &["Model", "BL", "Logic", "im/s", "paper", "vs BL8", "freeze"],
        &rows,
    );
    b.record("rows", series);

    // --- stressed configuration -----------------------------------------
    // In our calibrated substrate the weight streams are sequential
    // within each kernel region (row hits), so BL8 efficiency leaves a
    // comfortable margin over the supply threshold (one PC slot feeds a
    // chain when eff >= 70.3%) and the paper's ~2% R50 burst-length
    // sensitivity sits inside the margin. To demonstrate the mechanism
    // the paper describes, we re-run R50 on a degraded controller whose
    // inter-burst gap is 8 cycles (a conservative PHY that re-steers the
    // pipeline between bursts): small bursts now amortize the gap badly,
    // the bottleneck layer freezes at BL8 and recovers at BL32.
    let mut stressed = device.clone();
    stressed.hbm_timing.t_rd_gap = 8;
    let mut srows = Vec::new();
    let mut sseries = Json::Arr(vec![]);
    let net = zoo::by_name("resnet50").unwrap();
    let mut base: Option<f64> = None;
    for bl in [8u32, 16, 32] {
        let mut o = CompilerOptions::default();
        o.burst_length = BurstLengthPolicy::Fixed(bl);
        let plan = compile(&net, &stressed, &o).unwrap();
        let rep = simulate(&net, &plan, &cfg).unwrap();
        let rel = base.map(|x| rep.throughput / x).unwrap_or(1.0);
        base.get_or_insert(rep.throughput);
        srows.push(vec![
            "resnet50*".into(),
            bl.to_string(),
            format!("{:.0}", rep.throughput),
            format!("{rel:.3}x"),
            format!("{:.4}", rep.freeze_fraction),
        ]);
        let mut jo = Json::obj();
        jo.set("model", "resnet50_stressed_gap8")
            .set("burst", bl)
            .set("im_s", rep.throughput)
            .set("relative_to_bl8", rel)
            .set("freeze_fraction", rep.freeze_fraction);
        sseries.push(jo);
    }
    println!("\nstressed (8-cycle inter-burst gap — demonstrates the §VI-A mechanism):");
    b.table(&["Model", "BL", "im/s", "vs BL8", "freeze"], &srows);
    b.record("stressed_rows", sseries);
    b.finish();
}
