//! Table I — weight vs activation memory for the six-model zoo at
//! minimum parallelism, with the NX2100 140 Mb shading rule.

use h2pipe::bench_harness::Bench;
use h2pipe::compiler::memory_breakdown;
use h2pipe::config::{CompilerOptions, DeviceConfig};
use h2pipe::nn::zoo;
use h2pipe::util::Json;

fn main() {
    let mut b = Bench::new("table1_memory");
    let device = DeviceConfig::stratix10_nx2100();
    let opts = CompilerOptions::default();

    // paper rows (Mb) for the diff column
    let paper: &[(&str, f64, f64)] = &[
        ("MobileNetV1", 35.0, 11.0),
        ("MobileNetV2", 29.0, 15.0),
        ("MobileNetV3", 32.0, 12.0),
        ("ResNet-18", 102.0, 12.0),
        ("ResNet-50", 219.0, 57.0),
        ("VGG-16", 1204.0, 14.0),
    ];

    let mut rows = Vec::new();
    let mut series = Json::Arr(vec![]);
    for (net, (pname, pw, pa)) in zoo::table1_models().iter().zip(paper) {
        assert_eq!(&net.name, pname);
        let m = memory_breakdown(net, &opts);
        let w_mb = m.weight_bits as f64 / 1e6;
        let a_mb = m.act_bits as f64 / 1e6;
        rows.push(vec![
            net.name.clone(),
            format!("{w_mb:.0}"),
            format!("{pw:.0}"),
            format!("{a_mb:.0}"),
            format!("{pa:.0}"),
            format!("{:.1}%", 100.0 * m.act_fraction()),
            if m.exceeds(&device) { "SHADED".into() } else { "fits".into() },
        ]);
        let mut o = Json::obj();
        o.set("model", net.name.as_str())
            .set("weight_mb", w_mb)
            .set("act_mb", a_mb)
            .set("act_fraction", m.act_fraction())
            .set("exceeds_device", m.exceeds(&device))
            .set("paper_weight_mb", *pw)
            .set("paper_act_mb", *pa);
        series.push(o);
    }
    b.table(
        &["Model", "W (Mb)", "paper W", "A (Mb)", "paper A", "Act %", "NX2100"],
        &rows,
    );
    b.record("rows", series);
    b.time("memory_breakdown_all_models", 1, h2pipe::bench_harness::scaled(10, 1) as u32, || {
        for net in zoo::table1_models() {
            std::hint::black_box(memory_breakdown(&net, &opts));
        }
    });
    b.finish();
}
