//! §Perf — hot-path microbenchmarks for the optimization pass.
//!
//! Measures the three L3 hot loops in isolation so EXPERIMENTS.md §Perf
//! can record before/after numbers per optimization:
//!   * HBM pseudo-channel tick rate (the inner loop of every experiment),
//!   * full-pipeline simulation rate (model-cycles/s),
//!   * compiler end-to-end time,
//!   * PJRT artifact execution latency (the serving hot path).

use h2pipe::bench_harness::Bench;
use h2pipe::compiler::compile;
use h2pipe::config::{CompilerOptions, DeviceConfig};
use h2pipe::hbm::controller::{Dir, PcTuning, PseudoChannel, Request};
use h2pipe::hbm::CmdBus;
use h2pipe::nn::zoo;
use h2pipe::sim::pipeline::{PipelineSim, SimConfig};
use h2pipe::util::{Json, XorShift64};

fn main() {
    let mut b = Bench::new("perf_hotpath");
    let device = DeviceConfig::stratix10_nx2100();
    use h2pipe::bench_harness::scaled;

    // 1. HBM controller tick rate.
    let ticks = scaled(2_000_000, 100_000);
    let m = b.time("hbm_pc_tick_2M_saturated", 1, scaled(5, 1) as u32, || {
        let mut pc = PseudoChannel::new(&device.hbm, &device.hbm_timing, PcTuning::default());
        let mut rng = XorShift64::new(1);
        let mut id = 0u64;
        for _ in 0..ticks {
            if pc.can_accept(8) {
                pc.push(Request { id, dir: Dir::Read, addr: rng.next_below(1 << 26) & !31, burst: 8 });
                id += 1;
            }
            let mut bus = CmdBus::new();
            pc.tick(&mut bus);
            pc.drain_completions();
        }
    });
    let tick_rate = ticks as f64 / m.mean_s;
    println!("  -> {:.1} M HBM ticks/s", tick_rate / 1e6);
    b.record("hbm_ticks_per_s", tick_rate);

    // 2. Pipeline simulation rate (ResNet-50 hybrid, 3 images), on the
    // event-driven fast path (the default) and on the exact per-tick
    // reference path. Both produce byte-identical reports (see
    // tests/integration_eventsim.rs); the ratio is the headline win of
    // the skip-ahead scheduler.
    let net = zoo::resnet50();
    let plan = compile(&net, &device, &CompilerOptions::default()).unwrap();
    let cfg = SimConfig {
        images: scaled(3, 2),
        warmup_images: 1,
        exact_stepping: false,
        ..SimConfig::default()
    };
    let mut core_cycles = 0u64;
    let m = b.time("pipeline_sim_resnet50_event", scaled(1, 0) as u32, scaled(3, 1) as u32, || {
        let mut sim = PipelineSim::new(&net, &plan).unwrap();
        let rep = sim.run(&cfg).unwrap();
        core_cycles = rep.core_cycles;
    });
    let sim_rate = core_cycles as f64 / m.mean_s;
    println!("  -> {:.1} M model-cycles/s ({core_cycles} cycles)", sim_rate / 1e6);
    b.record("sim_model_cycles_per_s", sim_rate);

    // 2a. Exact per-tick reference path on the same workload.
    let slow_cfg = SimConfig { exact_stepping: true, ..cfg.clone() };
    let m = b.time("pipeline_sim_resnet50_exact", 0, scaled(2, 1) as u32, || {
        let mut sim = PipelineSim::new(&net, &plan).unwrap();
        let rep = sim.run(&slow_cfg).unwrap();
        core_cycles = rep.core_cycles;
    });
    let exact_rate = core_cycles as f64 / m.mean_s;
    let speedup = sim_rate / exact_rate;
    println!(
        "  -> {:.1} M model-cycles/s exact ({speedup:.1}x event-path speedup)",
        exact_rate / 1e6
    );
    b.record("sim_exact_cycles_per_s", exact_rate);
    b.record("sim_event_speedup", speedup);
    if h2pipe::bench_harness::full_run() {
        // Conservative floor: the measured win is far larger (see
        // BENCH_10.json); this guards against the fast path silently
        // degenerating into per-tick stepping.
        assert!(speedup >= 3.0, "event path speedup regressed: {speedup:.2}x < 3x");
    } else if speedup < 1.0 {
        println!("  (smoke run: speedup {speedup:.2}x below 1x — timing noise expected)");
    }

    // 2b. Probe plumbing overhead: the same run with a NullProbe attached
    // (every hook a no-op) isolates the cost of the observability wiring
    // itself. The acceptance bar is <5% vs the unprobed rate above.
    let m = b.time("pipeline_sim_resnet50_nullprobe", scaled(1, 0) as u32, scaled(3, 1) as u32, || {
        let mut probe = h2pipe::obs::NullProbe::new(4096);
        let mut sim = PipelineSim::new(&net, &plan).unwrap();
        let rep = sim.run_probed(&cfg, &mut probe).unwrap();
        core_cycles = rep.core_cycles;
    });
    let probed_rate = core_cycles as f64 / m.mean_s;
    let overhead = if probed_rate > 0.0 { sim_rate / probed_rate - 1.0 } else { f64::NAN };
    println!(
        "  -> {:.1} M model-cycles/s with NullProbe ({:+.1}% overhead)",
        probed_rate / 1e6,
        overhead * 100.0
    );
    b.record("sim_nullprobe_cycles_per_s", probed_rate);
    b.record("sim_probe_overhead_frac", overhead);

    // 2c. Fleet co-simulation rate (ResNet-18 split across 2 devices),
    // event-driven vs exact — the same scheduler drives every shard on a
    // shared clock plus the link-exchange events.
    let fnet = zoo::resnet18();
    let pp = h2pipe::cluster::partition(
        &fnet,
        &device,
        &CompilerOptions::default(),
        &h2pipe::cluster::PartitionOptions { shards: Some(2), max_shards: 2 },
    )
    .unwrap();
    let fleet = h2pipe::cluster::FleetSim::new(&pp).unwrap();
    let fcfg = h2pipe::cluster::FleetConfig {
        images: scaled(4, 2),
        warmup_images: 1,
        exact_stepping: false,
        ..h2pipe::cluster::FleetConfig::default()
    };
    let mut fleet_cycles = 0u64;
    let m = b.time("fleet_sim_resnet18_2shard_event", 0, scaled(3, 1) as u32, || {
        let rep = fleet.run(&fcfg).unwrap();
        fleet_cycles = rep.core_cycles;
    });
    let fleet_rate = fleet_cycles as f64 / m.mean_s;
    println!("  -> {:.1} M model-cycles/s ({fleet_cycles} cycles)", fleet_rate / 1e6);
    b.record("fleet_event_cycles_per_s", fleet_rate);
    let fslow_cfg = h2pipe::cluster::FleetConfig { exact_stepping: true, ..fcfg.clone() };
    let m = b.time("fleet_sim_resnet18_2shard_exact", 0, scaled(2, 1) as u32, || {
        let rep = fleet.run(&fslow_cfg).unwrap();
        fleet_cycles = rep.core_cycles;
    });
    let fleet_exact_rate = fleet_cycles as f64 / m.mean_s;
    let fleet_speedup = fleet_rate / fleet_exact_rate;
    println!(
        "  -> {:.1} M model-cycles/s exact ({fleet_speedup:.1}x event-path speedup)",
        fleet_exact_rate / 1e6
    );
    b.record("fleet_exact_cycles_per_s", fleet_exact_rate);
    b.record("fleet_event_speedup", fleet_speedup);

    // 3. Compiler end-to-end.
    b.time("compile_resnet50", 1, scaled(10, 2) as u32, || {
        std::hint::black_box(compile(&net, &device, &CompilerOptions::default()).unwrap());
    });

    // 4. Runtime execution latency (the serving hot path): the reference
    // interpreter offline, the PJRT artifact with `--features pjrt`.
    let art = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    let rt = h2pipe::runtime::Runtime::cpu(&art).unwrap();
    match rt.load("cifarnet") {
        Ok(exe) => {
            let img = vec![1i32; 32 * 32 * 3];
            let label = format!("runtime_cifarnet_execute_{}", rt.backend_name());
            let m = b.time(&label, scaled(3, 1) as u32, scaled(30, 3) as u32, || {
                std::hint::black_box(exe.run_i32(&img, &[32, 32, 3]).unwrap());
            });
            b.record("runtime_backend", rt.backend_name());
            b.record("runtime_execute_ms", m.mean_ms());
        }
        Err(e) => println!("  (runtime measurement skipped: {e:#})"),
    }

    let mut targets = Json::obj();
    targets
        .set("sim_model_cycles_per_s_target", 50_000_000u64)
        .set("note", "see EXPERIMENTS.md §Perf for the iteration log");
    b.record("targets", targets);

    // Machine-readable summary line for CI to grep off stdout (the full
    // JSON also lands under target/bench_results/).
    let mut summary = Json::obj();
    summary
        .set("bench", "perf_hotpath")
        .set("hbm_mticks_per_s", tick_rate / 1e6)
        .set("sim_event_mcycles_per_s", sim_rate / 1e6)
        .set("sim_exact_mcycles_per_s", exact_rate / 1e6)
        .set("sim_event_speedup", speedup)
        .set("fleet_event_mcycles_per_s", fleet_rate / 1e6)
        .set("fleet_exact_mcycles_per_s", fleet_exact_rate / 1e6)
        .set("fleet_event_speedup", fleet_speedup);
    println!("PERF_HOTPATH_JSON {summary}");
    b.finish();
}
