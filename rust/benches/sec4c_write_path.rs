//! §IV-C — the boot-time write-path width trade-off.
//!
//! Sweeps the write-path width from 8 to 256 bits on the VGG-16 plan (the
//! heaviest download: ~150 MB of HBM-resident weights) and reports boot
//! time vs register cost. Paper reference: the default 30-bit path saves
//! >3000 registers vs a straightforward 256-bit bus.

use h2pipe::bench_harness::Bench;
use h2pipe::compiler::compile;
use h2pipe::config::{CompilerOptions, DeviceConfig};
use h2pipe::coordinator::boot_weights;
use h2pipe::nn::zoo;
use h2pipe::util::Json;

fn main() {
    let mut b = Bench::new("sec4c_write_path");
    let device = DeviceConfig::stratix10_nx2100();
    let net = zoo::vgg16();

    let mut rows = Vec::new();
    let mut series = Json::Arr(vec![]);
    let mut regs_at_30 = 0u64;
    let mut regs_at_256 = 0u64;
    // smoke runs keep the endpoints the register-savings claim needs
    let widths: &[u32] = if h2pipe::bench_harness::full_run() {
        &[8, 16, 30, 64, 128, 256]
    } else {
        &[16, 30, 256]
    };
    for &width in widths {
        let mut o = CompilerOptions::default();
        o.write_path_bits = width;
        let plan = compile(&net, &device, &o).unwrap();
        let r = boot_weights(&plan);
        if width == 30 {
            regs_at_30 = r.write_path_registers;
        }
        if width == 256 {
            regs_at_256 = r.write_path_registers;
        }
        rows.push(vec![
            width.to_string(),
            format!("{:.1}", r.seconds * 1e3),
            r.write_path_registers.to_string(),
            format!("{:.2}", r.hbm_write_efficiency),
            format!("{}", r.bytes >> 20),
        ]);
        let mut jo = Json::obj();
        jo.set("width_bits", width)
            .set("boot_ms", r.seconds * 1e3)
            .set("registers", r.write_path_registers)
            .set("write_efficiency", r.hbm_write_efficiency)
            .set("hbm_mib", r.bytes >> 20);
        series.push(jo);
    }
    b.table(&["width(b)", "boot(ms)", "regs", "wr eff", "HBM MiB"], &rows);
    b.record("sweep", series);

    let saved = regs_at_256.saturating_sub(regs_at_30);
    println!("registers saved 256b -> 30b: {saved} (paper: >3000)");
    let mut paper = Json::obj();
    paper.set("registers_saved_256_to_30", saved).set("paper_claim_min", 3000u64);
    b.record("paper_reference", paper);
    assert!(saved > 2500, "register savings {saved} below the paper's claim region");
    b.finish();
}
