//! Fig. 3b — saturated HBM read latency (min/avg/max) vs burst length.
//!
//! Paper reference points: average falls with burst length to ~400 ns at
//! BL32; minimum is the unloaded latency; the worst case at BL >= 8
//! (~1214 ns) sizes the 512-deep last-stage FIFOs of §IV-A.

use h2pipe::bench_harness::Bench;
use h2pipe::config::DeviceConfig;
use h2pipe::hbm::traffic::controller_to_core_cycles;
use h2pipe::hbm::{AddressPattern, TrafficConfig, TrafficGen};
use h2pipe::util::Json;

fn main() {
    let mut b = Bench::new("fig3b_hbm_latency");
    let device = DeviceConfig::stratix10_nx2100();
    let gen = TrafficGen::new(&device);

    let txns = h2pipe::bench_harness::scaled(10_000, 400);
    let mut rows = Vec::new();
    let mut series = Json::Arr(vec![]);
    let mut worst_bl8plus: f64 = 0.0;
    for bl in [1u32, 2, 4, 8, 16, 32] {
        let mut cfg = TrafficConfig::new(AddressPattern::Random, bl);
        cfg.transactions = txns;
        let r = gen.run(&cfg);
        if bl >= 8 {
            worst_bl8plus = worst_bl8plus.max(r.read_lat_max_ns);
        }
        rows.push(vec![
            bl.to_string(),
            format!("{:.0}", r.read_lat_min_ns),
            format!("{:.0}", r.read_lat_avg_ns),
            format!("{:.0}", r.read_lat_max_ns),
            format!("{:.0}", r.read_lat_p99_ns),
        ]);
        let mut o = Json::obj();
        o.set("burst", bl)
            .set("min_ns", r.read_lat_min_ns)
            .set("avg_ns", r.read_lat_avg_ns)
            .set("max_ns", r.read_lat_max_ns)
            .set("p99_ns", r.read_lat_p99_ns);
        series.push(o);
    }
    b.table(&["BL", "min(ns)", "avg(ns)", "max(ns)", "p99(ns)"], &rows);
    b.record("series", series);

    // FIFO sizing check (§III-B): worst-case latency at BL>=8 expressed in
    // 300 MHz core cycles must be covered by the 512-word FIFO depth.
    let worst_core_cycles =
        controller_to_core_cycles((worst_bl8plus / 2.5) as u64, 400, device.core_mhz);
    let mut sizing = Json::obj();
    sizing
        .set("worst_case_ns_bl8plus", worst_bl8plus)
        .set("worst_case_core_cycles", worst_core_cycles)
        .set("fifo_depth_words", 512u64)
        .set("covered", worst_core_cycles <= 512);
    b.record("fifo_sizing", sizing);
    println!(
        "worst-case BL>=8 latency {worst_bl8plus:.0} ns = {worst_core_cycles} core cycles \
         (paper: 1214 ns = 364 cycles; 512-deep FIFO covers it: {})",
        worst_core_cycles <= 512
    );

    let mut paper = Json::obj();
    paper.set("avg_ns_bl32", 400.0).set("worst_ns_bl8plus", 1214.0).set("fifo_words", 512u64);
    b.record("paper_reference", paper);
    b.finish();
}
