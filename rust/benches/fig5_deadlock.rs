//! Fig. 5 — the shared-pseudo-channel deadlock, and the credit fix.
//!
//! Reproduces §V-A: three layers sharing one HBM-to-fabric DCFIFO under
//! ready/valid flow control deadlock by head-of-line blocking; the same
//! scenario under the credit protocol completes, at no throughput cost
//! when no hazard exists. Sweeps buffer depths to show the hazard region.

use h2pipe::bench_harness::Bench;
use h2pipe::fabric::{run_shared_pc_pipeline, FlowControl, PipelineOutcome};
use h2pipe::fabric::deadlock::ScenarioConfig;
use h2pipe::util::Json;

fn outcome_str(o: &PipelineOutcome) -> String {
    match o {
        PipelineOutcome::Completed { cycles } => format!("completed in {cycles}"),
        PipelineOutcome::Deadlocked { cycle, head_layer, starved_layer } => {
            format!("DEADLOCK @{cycle} (head=L{head_layer}, starved=L{starved_layer})")
        }
    }
}

fn main() {
    let mut b = Bench::new("fig5_deadlock");

    // The paper's scenario.
    let cfg = ScenarioConfig::default();
    let rv = run_shared_pc_pipeline(FlowControl::ReadyValid, &cfg);
    let cr = run_shared_pc_pipeline(FlowControl::Credit, &cfg);
    println!("Fig.5 scenario, ready/valid: {}", outcome_str(&rv));
    println!("Fig.5 scenario, credit:      {}", outcome_str(&cr));
    assert!(matches!(rv, PipelineOutcome::Deadlocked { .. }));
    assert!(matches!(cr, PipelineOutcome::Completed { .. }));

    // Sweep burst-FIFO depth: where does ready/valid stop deadlocking?
    let mut rows = Vec::new();
    let mut series = Json::Arr(vec![]);
    for depth in [2usize, 4, 8, 16, 32, 64, 128] {
        let c = ScenarioConfig { burst_fifo_capacity: depth, ..ScenarioConfig::default() };
        let rv = run_shared_pc_pipeline(FlowControl::ReadyValid, &c);
        let cr = run_shared_pc_pipeline(FlowControl::Credit, &c);
        let cr_cycles = match cr {
            PipelineOutcome::Completed { cycles } => cycles,
            _ => unreachable!("credit must complete"),
        };
        rows.push(vec![
            depth.to_string(),
            outcome_str(&rv),
            format!("completed in {cr_cycles}"),
        ]);
        let mut o = Json::obj();
        o.set("burst_fifo_depth", depth)
            .set("ready_valid_deadlocks", matches!(rv, PipelineOutcome::Deadlocked { .. }))
            .set("credit_cycles", cr_cycles);
        series.push(o);
    }
    b.table(&["burst FIFO depth", "ready/valid", "credit"], &rows);
    b.record("depth_sweep", series);

    // Throughput parity when no hazard exists (symmetric demand).
    let sym = ScenarioConfig { weights_per_item: [1, 1, 1], ..ScenarioConfig::default() };
    let (PipelineOutcome::Completed { cycles: rv_c }, PipelineOutcome::Completed { cycles: cr_c }) = (
        run_shared_pc_pipeline(FlowControl::ReadyValid, &sym),
        run_shared_pc_pipeline(FlowControl::Credit, &sym),
    ) else {
        panic!("symmetric scenario must complete under both protocols");
    };
    println!("symmetric demand: ready/valid {rv_c} cycles, credit {cr_c} cycles");
    let mut parity = Json::obj();
    parity.set("ready_valid_cycles", rv_c).set("credit_cycles", cr_c);
    b.record("no_hazard_parity", parity);

    let iters = h2pipe::bench_harness::scaled(10, 2) as u32;
    b.time("fig5_scenario_pair", 1, iters, || {
        let c = ScenarioConfig::default();
        std::hint::black_box(run_shared_pc_pipeline(FlowControl::ReadyValid, &c));
        std::hint::black_box(run_shared_pc_pipeline(FlowControl::Credit, &c));
    });
    b.finish();
}
