//! Minimal, offline-compatible subset of the `anyhow` error-handling API.
//!
//! The offline crate set this workspace builds against has no registry
//! access, so the real `anyhow` cannot be fetched. This vendored path
//! crate implements exactly the surface the codebase uses, with the same
//! names and semantics:
//!
//! * [`Error`] — a context-chained, message-based error value;
//! * [`Result<T>`] — `Result` with [`Error`] as the default error type;
//! * [`anyhow!`], [`bail!`], [`ensure!`] — the construction macros;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result`
//!   (both std errors and [`Error`] itself) and on `Option`;
//! * `{e}` prints the outermost message, `{e:#}` the full `": "`-joined
//!   chain, and `{e:?}` an anyhow-style "Caused by" listing.
//!
//! Unsupported pieces of real anyhow (downcasting, backtraces) are
//! intentionally absent — nothing in this workspace uses them.

// `anyhow!("plain literal")` must expand through `format!` so inline
// captures (`anyhow!("got {x}")`) work; allow the no-arg case in-crate.
#![allow(clippy::useless_format)]

use std::fmt;

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-chained error. `chain[0]` is the outermost message; each
/// `.context(..)` pushes a new front entry, mirroring anyhow's wrapping.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (what `.context(..)` does).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The `": "`-joined cause chain, outermost first (the `{:#}` form).
    pub fn chain_string(&self) -> String {
        self.chain.join(": ")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain_string())
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that
// is what makes the blanket `From` below coherent (exactly as in real
// anyhow) and lets `?` convert any std error into an `Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Self { chain }
    }
}

mod private {
    /// Unifies "a std error" and "already an `Error`" for the [`super::Context`]
    /// impl on `Result` — the sealed-trait trick real anyhow uses.
    pub trait IntoError {
        fn into_error(self) -> super::Error;
    }

    impl IntoError for super::Error {
        fn into_error(self) -> super::Error {
            self
        }
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> super::Error {
            super::Error::from(self)
        }
    }
}

/// Extension trait providing `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Like [`Context::context`], evaluating the message lazily.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: private::IntoError> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($fmt:literal $(, $($arg:tt)*)?) => {
        $crate::Error::msg(format!($fmt $(, $($arg)*)?))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file gone")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Error::from(io_err()).context("loading plan");
        assert_eq!(format!("{e}"), "loading plan");
        assert_eq!(format!("{e:#}"), "loading plan: file gone");
    }

    #[test]
    fn debug_prints_caused_by() {
        let e = Error::msg("inner").context("outer");
        let d = format!("{e:?}");
        assert!(d.starts_with("outer"));
        assert!(d.contains("Caused by:"));
        assert!(d.contains("inner"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<u32> {
            Ok(s.parse::<u32>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn context_on_result_option_and_error() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("ctx").unwrap_err();
        assert_eq!(format!("{e:#}"), "ctx: file gone");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "thing")).unwrap_err();
        assert_eq!(format!("{e}"), "missing thing");

        // .context on a Result that already carries an `Error`
        let r: Result<()> = Err(anyhow!("base"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: base");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky {}", x);
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(format!("{:#}", f(12).unwrap_err()).contains("x too big: 12"));
        assert!(format!("{:#}", f(7).unwrap_err()).contains("unlucky 7"));
        let from_string: Error = anyhow!(String::from("owned message"));
        assert_eq!(format!("{from_string}"), "owned message");
    }
}
